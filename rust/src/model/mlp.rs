//! ReLU multi-layer perceptron — the paper's §C.2 Fashion-MNIST
//! architecture family (784-256-128-C), with arbitrary hidden widths.

use super::{softmax_xent_backward, softmax_xent_eval, Model};
use crate::util::linalg::{matmul, matmul_a_bt, matmul_at_b, relu, relu_backward};
use crate::util::rng::Pcg64;

/// Fully connected ReLU network.
///
/// Layer `l` maps width `in_l → out_l`; parameters are stored flat as
/// `[W_0 (out×in row-major), b_0, W_1, b_1, …]` — one contiguous
/// `d`-vector so compressors see the whole gradient at once.
#[derive(Clone, Debug)]
pub struct Mlp {
    /// Widths `[inputs, hidden…, classes]`.
    pub widths: Vec<usize>,
}

impl Mlp {
    pub fn new(inputs: usize, hidden: Vec<usize>, classes: usize) -> Self {
        assert!(inputs > 0 && classes > 1);
        assert!(hidden.iter().all(|&h| h > 0), "zero-width hidden layer");
        let mut widths = Vec::with_capacity(hidden.len() + 2);
        widths.push(inputs);
        widths.extend(hidden);
        widths.push(classes);
        Self { widths }
    }

    fn layers(&self) -> usize {
        self.widths.len() - 1
    }

    fn classes(&self) -> usize {
        *self.widths.last().unwrap()
    }

    /// Offset of layer `l`'s weights within the flat parameter vector.
    fn layer_offset(&self, l: usize) -> usize {
        let mut off = 0;
        for i in 0..l {
            off += self.widths[i] * self.widths[i + 1] + self.widths[i + 1];
        }
        off
    }

    /// Forward pass retaining activations: returns (per-layer outputs,
    /// final logits). `acts[0]` is the input batch; `acts[l]` the
    /// post-ReLU activation feeding layer `l`.
    fn forward(&self, params: &[f32], x: &[f32], batch: usize) -> Vec<Vec<f32>> {
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(self.layers() + 1);
        acts.push(x.to_vec());
        for l in 0..self.layers() {
            let (in_w, out_w) = (self.widths[l], self.widths[l + 1]);
            let off = self.layer_offset(l);
            let w = &params[off..off + out_w * in_w];
            let b = &params[off + out_w * in_w..off + out_w * in_w + out_w];
            let mut h = vec![0.0f32; batch * out_w];
            matmul_a_bt(&mut h, &acts[l], w, batch, in_w, out_w);
            for i in 0..batch {
                for (v, &bj) in h[i * out_w..(i + 1) * out_w].iter_mut().zip(b) {
                    *v += bj;
                }
            }
            if l + 1 < self.layers() {
                relu(&mut h);
            }
            acts.push(h);
        }
        acts
    }
}

impl Model for Mlp {
    fn dim(&self) -> usize {
        self.layer_offset(self.layers())
    }

    fn loss_grad(&self, params: &[f32], x: &[f32], y: &[usize], grad: &mut [f32]) -> f32 {
        assert_eq!(params.len(), self.dim());
        assert_eq!(grad.len(), self.dim());
        let batch = y.len();
        assert_eq!(x.len(), batch * self.widths[0], "batch feature shape");
        let mut acts = self.forward(params, x, batch);
        let classes = self.classes();
        // Softmax-CE backward on the logits (the last activation).
        let mut delta = acts.pop().unwrap(); // batch×classes
        let loss = softmax_xent_backward(&mut delta, y, classes);
        grad.fill(0.0);
        // Backprop through layers (last to first).
        for l in (0..self.layers()).rev() {
            let (in_w, out_w) = (self.widths[l], self.widths[l + 1]);
            let off = self.layer_offset(l);
            let a_in = &acts[l]; // batch×in_w (post-ReLU of previous layer)
            // dW = deltaᵀ · a_in  (out×in).
            matmul_at_b(
                &mut grad[off..off + out_w * in_w],
                &delta,
                a_in,
                out_w,
                batch,
                in_w,
            );
            // db = column sums of delta.
            let db = &mut grad[off + out_w * in_w..off + out_w * in_w + out_w];
            for i in 0..batch {
                for (dbj, &dl) in db.iter_mut().zip(&delta[i * out_w..(i + 1) * out_w]) {
                    *dbj += dl;
                }
            }
            if l > 0 {
                // delta_prev = delta · W, masked by ReLU'(a_in).
                let w = &params[off..off + out_w * in_w];
                let mut prev = vec![0.0f32; batch * in_w];
                matmul(&mut prev, &delta, w, batch, out_w, in_w);
                relu_backward(&mut prev, a_in);
                delta = prev;
            }
        }
        loss
    }

    fn evaluate(&self, params: &[f32], x: &[f32], y: &[usize]) -> (f64, f64) {
        let batch = y.len();
        let acts = self.forward(params, x, batch);
        let mut logits = acts.last().unwrap().clone();
        softmax_xent_eval(&mut logits, y, self.classes())
    }

    fn init(&self, rng: &mut Pcg64) -> Vec<f32> {
        // He initialization for ReLU layers; final layer Xavier-ish.
        let mut p = vec![0.0f32; self.dim()];
        for l in 0..self.layers() {
            let (in_w, out_w) = (self.widths[l], self.widths[l + 1]);
            let off = self.layer_offset(l);
            let std = (2.0 / in_w as f32).sqrt();
            rng.fill_normal(&mut p[off..off + out_w * in_w], 0.0, std);
        }
        p
    }

    fn describe(&self) -> String {
        let w: Vec<String> = self.widths.iter().map(|x| x.to_string()).collect();
        format!("mlp {}", w.join("-"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::grad_check;

    #[test]
    fn dims_add_up() {
        let m = Mlp::new(784, vec![256, 128], 10);
        assert_eq!(m.dim(), 784 * 256 + 256 + 256 * 128 + 128 + 128 * 10 + 10);
        assert_eq!(m.layers(), 3);
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let m = Mlp::new(5, vec![7, 6], 3);
        let mut rng = Pcg64::seed_from(1);
        let batch = 4;
        let mut x = vec![0.0; batch * 5];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let y = vec![0, 2, 1, 2];
        grad_check(&m, &x, &y, 2);
    }

    #[test]
    fn single_layer_equals_linear_model() {
        use crate::model::SoftmaxRegression;
        let mlp = Mlp::new(4, vec![], 3);
        let lin = SoftmaxRegression::new(4, 3);
        assert_eq!(mlp.dim(), lin.dim());
        let mut rng = Pcg64::seed_from(3);
        let params = lin.init(&mut rng);
        let mut x = vec![0.0; 6 * 4];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let y = vec![0, 1, 2, 0, 1, 2];
        let mut g1 = vec![0.0; mlp.dim()];
        let mut g2 = vec![0.0; lin.dim()];
        let l1 = mlp.loss_grad(&params, &x, &y, &mut g1);
        let l2 = lin.loss_grad(&params, &x, &y, &mut g2);
        assert!((l1 - l2).abs() < 1e-5);
        for (a, b) in g1.iter().zip(&g2) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn learns_xor_style_task() {
        // Non-linearly-separable data: MLP must beat a linear model.
        let m = Mlp::new(2, vec![16], 2);
        let mut rng = Pcg64::seed_from(4);
        let mut params = m.init(&mut rng);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..256 {
            let a = rng.range_f32(-1.0, 1.0);
            let b = rng.range_f32(-1.0, 1.0);
            x.push(a);
            x.push(b);
            y.push(if (a > 0.0) != (b > 0.0) { 1 } else { 0 });
        }
        let mut grad = vec![0.0; m.dim()];
        for _ in 0..800 {
            m.loss_grad(&params, &x, &y, &mut grad);
            for (p, g) in params.iter_mut().zip(&grad) {
                *p -= 0.5 * g;
            }
        }
        let (_, acc) = m.evaluate(&params, &x, &y);
        assert!(acc > 0.9, "XOR acc {acc}");
    }

    #[test]
    fn init_is_deterministic_and_nonzero() {
        let m = Mlp::new(10, vec![8], 4);
        let a = m.init(&mut Pcg64::seed_from(5));
        let b = m.init(&mut Pcg64::seed_from(5));
        assert_eq!(a, b);
        assert!(a.iter().any(|&v| v != 0.0));
        // Biases start at zero.
        let off = 10 * 8;
        assert!(a[off..off + 8].iter().all(|&v| v == 0.0));
    }
}
