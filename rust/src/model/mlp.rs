//! ReLU multi-layer perceptron — the paper's §C.2 Fashion-MNIST
//! architecture family (784-256-128-C), with arbitrary hidden widths.
//!
//! The forward/backward pass is built on the packed GEMM in
//! [`crate::util::linalg`]: every layer is a single [`gemm_with`] call
//! with the bias-add (+ ReLU) fused into the store loop, and every
//! intermediate lives in the caller's [`ModelWorkspace`] — steady-state
//! `loss_grad_ws` performs **zero** heap allocations (DESIGN.md §9,
//! pinned by `tests/zero_alloc.rs`).

use super::{ensure_len, softmax_xent_backward, softmax_xent_eval, Model, ModelWorkspace};
use crate::util::linalg::{gemm_with, relu_backward, Epilogue, MatLayout};
use crate::util::rng::Pcg64;

/// Fully connected ReLU network.
///
/// Layer `l` maps width `in_l → out_l`; parameters are stored flat as
/// `[W_0 (out×in row-major), b_0, W_1, b_1, …]` — one contiguous
/// `d`-vector so compressors see the whole gradient at once.
#[derive(Clone, Debug)]
pub struct Mlp {
    /// Widths `[inputs, hidden…, classes]`.
    pub widths: Vec<usize>,
}

impl Mlp {
    pub fn new(inputs: usize, hidden: Vec<usize>, classes: usize) -> Self {
        assert!(inputs > 0 && classes > 1);
        assert!(hidden.iter().all(|&h| h > 0), "zero-width hidden layer");
        let mut widths = Vec::with_capacity(hidden.len() + 2);
        widths.push(inputs);
        widths.extend(hidden);
        widths.push(classes);
        Self { widths }
    }

    fn layers(&self) -> usize {
        self.widths.len() - 1
    }

    fn classes(&self) -> usize {
        *self.widths.last().unwrap()
    }

    /// Offset of layer `l`'s weights within the flat parameter vector.
    fn layer_offset(&self, l: usize) -> usize {
        let mut off = 0;
        for i in 0..l {
            off += self.widths[i] * self.widths[i + 1] + self.widths[i + 1];
        }
        off
    }

    /// Forward pass into the workspace: after the call `ws.acts[l]` holds
    /// layer `l`'s output (`batch × widths[l+1]`, post-ReLU for hidden
    /// layers, raw logits for the last). The input batch `x` is read in
    /// place — no copy. Bias-add and ReLU are fused into the GEMM store.
    fn forward_ws(&self, params: &[f32], x: &[f32], batch: usize, ws: &mut ModelWorkspace) {
        let layers = self.layers();
        ws.acts_for(layers);
        let ModelWorkspace { acts, gemm, .. } = ws;
        for l in 0..layers {
            let (in_w, out_w) = (self.widths[l], self.widths[l + 1]);
            let off = self.layer_offset(l);
            let w = &params[off..off + out_w * in_w];
            let b = &params[off + out_w * in_w..off + out_w * in_w + out_w];
            let (done, rest) = acts.split_at_mut(l);
            let h = &mut rest[0];
            ensure_len(h, batch * out_w);
            let input: &[f32] = if l == 0 { x } else { &done[l - 1] };
            let epilogue = if l + 1 < layers {
                Epilogue::BiasRelu(b)
            } else {
                Epilogue::Bias(b)
            };
            // h = input · Wᵀ (+ b, ReLU): W is stored out×in row-major,
            // i.e. the transpose of the logical in×out operand.
            gemm_with(
                gemm,
                h,
                input,
                MatLayout::Normal,
                w,
                MatLayout::Transpose,
                batch,
                in_w,
                out_w,
                false,
                epilogue,
            );
        }
    }
}

impl Model for Mlp {
    fn dim(&self) -> usize {
        self.layer_offset(self.layers())
    }

    fn loss_grad_ws(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[usize],
        grad: &mut [f32],
        ws: &mut ModelWorkspace,
    ) -> f32 {
        assert_eq!(params.len(), self.dim());
        assert_eq!(grad.len(), self.dim());
        let batch = y.len();
        assert_eq!(x.len(), batch * self.widths[0], "batch feature shape");
        self.forward_ws(params, x, batch, ws);
        let classes = self.classes();
        let layers = self.layers();
        // Softmax-CE backward on a copy of the logits (the activations
        // stay intact for the ReLU masks below).
        ws.delta.clear();
        ws.delta.extend_from_slice(&ws.acts[layers - 1]);
        let loss = softmax_xent_backward(&mut ws.delta, y, classes);
        let ModelWorkspace { acts, delta, delta2, gemm, .. } = ws;
        // Backprop through layers (last to first). Weight blocks are
        // overwritten by the GEMM (no full-`d` grad zeroing needed);
        // only the small bias blocks are cleared explicitly.
        for l in (0..layers).rev() {
            let (in_w, out_w) = (self.widths[l], self.widths[l + 1]);
            let off = self.layer_offset(l);
            let a_in: &[f32] = if l == 0 { x } else { &acts[l - 1] };
            // dW = deltaᵀ · a_in (out×in); delta is stored batch×out.
            gemm_with(
                gemm,
                &mut grad[off..off + out_w * in_w],
                delta,
                MatLayout::Transpose,
                a_in,
                MatLayout::Normal,
                out_w,
                batch,
                in_w,
                false,
                Epilogue::None,
            );
            // db = column sums of delta.
            let db = &mut grad[off + out_w * in_w..off + out_w * in_w + out_w];
            db.fill(0.0);
            for drow in delta.chunks_exact(out_w) {
                for (dbj, &dl) in db.iter_mut().zip(drow) {
                    *dbj += dl;
                }
            }
            if l > 0 {
                // delta_prev = delta · W, masked by ReLU'(a_in).
                let w = &params[off..off + out_w * in_w];
                ensure_len(delta2, batch * in_w);
                gemm_with(
                    gemm,
                    delta2,
                    delta,
                    MatLayout::Normal,
                    w,
                    MatLayout::Normal,
                    batch,
                    out_w,
                    in_w,
                    false,
                    Epilogue::None,
                );
                relu_backward(delta2, a_in);
                std::mem::swap(delta, delta2);
            }
        }
        loss
    }

    fn evaluate_ws(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[usize],
        ws: &mut ModelWorkspace,
    ) -> (f64, f64) {
        let batch = y.len();
        assert_eq!(x.len(), batch * self.widths[0], "batch feature shape");
        self.forward_ws(params, x, batch, ws);
        let logits = &mut ws.acts[self.layers() - 1];
        softmax_xent_eval(logits, y, self.classes())
    }

    fn init(&self, rng: &mut Pcg64) -> Vec<f32> {
        // He initialization for ReLU layers; final layer Xavier-ish.
        let mut p = vec![0.0f32; self.dim()];
        for l in 0..self.layers() {
            let (in_w, out_w) = (self.widths[l], self.widths[l + 1]);
            let off = self.layer_offset(l);
            let std = (2.0 / in_w as f32).sqrt();
            rng.fill_normal(&mut p[off..off + out_w * in_w], 0.0, std);
        }
        p
    }

    fn describe(&self) -> String {
        let w: Vec<String> = self.widths.iter().map(|x| x.to_string()).collect();
        format!("mlp {}", w.join("-"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::grad_check;

    #[test]
    fn dims_add_up() {
        let m = Mlp::new(784, vec![256, 128], 10);
        assert_eq!(m.dim(), 784 * 256 + 256 + 256 * 128 + 128 + 128 * 10 + 10);
        assert_eq!(m.layers(), 3);
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let m = Mlp::new(5, vec![7, 6], 3);
        let mut rng = Pcg64::seed_from(1);
        let batch = 4;
        let mut x = vec![0.0; batch * 5];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let y = vec![0, 2, 1, 2];
        grad_check(&m, &x, &y, 2);
    }

    #[test]
    fn single_layer_equals_linear_model() {
        use crate::model::SoftmaxRegression;
        let mlp = Mlp::new(4, vec![], 3);
        let lin = SoftmaxRegression::new(4, 3);
        assert_eq!(mlp.dim(), lin.dim());
        let mut rng = Pcg64::seed_from(3);
        let params = lin.init(&mut rng);
        let mut x = vec![0.0; 6 * 4];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let y = vec![0, 1, 2, 0, 1, 2];
        let mut g1 = vec![0.0; mlp.dim()];
        let mut g2 = vec![0.0; lin.dim()];
        let l1 = mlp.loss_grad(&params, &x, &y, &mut g1);
        let l2 = lin.loss_grad(&params, &x, &y, &mut g2);
        assert!((l1 - l2).abs() < 1e-5);
        for (a, b) in g1.iter().zip(&g2) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn workspace_reuse_is_consistent_across_batch_shapes() {
        // One workspace serving alternating batch sizes and repeated calls
        // must agree bitwise with throwaway-workspace calls.
        let m = Mlp::new(9, vec![11, 5], 4);
        let mut rng = Pcg64::seed_from(17);
        let params = m.init(&mut rng);
        let mut ws = ModelWorkspace::new();
        for &batch in &[6usize, 2, 6, 13, 1, 6] {
            let mut x = vec![0.0; batch * 9];
            rng.fill_normal(&mut x, 0.0, 1.0);
            let y: Vec<usize> = (0..batch).map(|i| i % 4).collect();
            let mut g_ws = vec![0.0; m.dim()];
            let mut g_fresh = vec![0.0; m.dim()];
            let l_ws = m.loss_grad_ws(&params, &x, &y, &mut g_ws, &mut ws);
            let l_fresh = m.loss_grad(&params, &x, &y, &mut g_fresh);
            assert_eq!(l_ws, l_fresh, "batch {batch}");
            assert_eq!(g_ws, g_fresh, "batch {batch}");
            let e_ws = m.evaluate_ws(&params, &x, &y, &mut ws);
            let e_fresh = m.evaluate(&params, &x, &y);
            assert_eq!(e_ws, e_fresh, "batch {batch}");
        }
    }

    #[test]
    fn stale_grad_buffer_is_fully_overwritten() {
        // loss_grad no longer zeroes the whole grad vector up front; every
        // coordinate must still be written (weights via overwriting GEMM,
        // biases via the explicit clear).
        let m = Mlp::new(5, vec![7], 3);
        let mut rng = Pcg64::seed_from(18);
        let params = m.init(&mut rng);
        let mut x = vec![0.0; 4 * 5];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let y = vec![0, 1, 2, 0];
        let mut g_clean = vec![0.0; m.dim()];
        m.loss_grad(&params, &x, &y, &mut g_clean);
        let mut g_dirty = vec![1e9f32; m.dim()];
        m.loss_grad(&params, &x, &y, &mut g_dirty);
        assert_eq!(g_clean, g_dirty);
    }

    #[test]
    fn learns_xor_style_task() {
        // Non-linearly-separable data: MLP must beat a linear model.
        let m = Mlp::new(2, vec![16], 2);
        let mut rng = Pcg64::seed_from(4);
        let mut params = m.init(&mut rng);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..256 {
            let a = rng.range_f32(-1.0, 1.0);
            let b = rng.range_f32(-1.0, 1.0);
            x.push(a);
            x.push(b);
            y.push(if (a > 0.0) != (b > 0.0) { 1 } else { 0 });
        }
        let mut grad = vec![0.0; m.dim()];
        let mut ws = ModelWorkspace::new();
        for _ in 0..800 {
            m.loss_grad_ws(&params, &x, &y, &mut grad, &mut ws);
            for (p, g) in params.iter_mut().zip(&grad) {
                *p -= 0.5 * g;
            }
        }
        let (_, acc) = m.evaluate(&params, &x, &y);
        assert!(acc > 0.9, "XOR acc {acc}");
    }

    #[test]
    fn init_is_deterministic_and_nonzero() {
        let m = Mlp::new(10, vec![8], 4);
        let a = m.init(&mut Pcg64::seed_from(5));
        let b = m.init(&mut Pcg64::seed_from(5));
        assert_eq!(a, b);
        assert!(a.iter().any(|&v| v != 0.0));
        // Biases start at zero.
        let off = 10 * 8;
        assert!(a[off..off + 8].iter().all(|&v| v == 0.0));
    }
}
