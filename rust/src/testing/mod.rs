//! A minimal property-based testing framework (the sandbox has no
//! `proptest`), used by unit tests across the crate and by
//! `rust/tests/property_suite.rs`.
//!
//! Design: generators are plain closures `FnMut(&mut Pcg64) -> T`; the
//! runner executes `cases` seeded deterministically from a base seed and,
//! on failure, retries with a simple halving shrink for `Vec`-valued
//! inputs before reporting the failing seed + minimal counterexample.

use crate::util::rng::Pcg64;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self { cases: 128, seed: 0x5eed }
    }
}

/// Run `prop` on `cfg.cases` generated inputs; panics with the failing
/// case index + seed on the first violation.
pub fn check<T, G, P>(cfg: PropConfig, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Pcg64) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let mut rng = Pcg64::new(cfg.seed, case as u64);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case} (seed {:#x}): {msg}\ninput: {input:?}",
                cfg.seed
            );
        }
    }
}

/// Like [`check`], but for `Vec<f32>` inputs: on failure, shrink by
/// repeatedly halving the vector (keeping whichever half still fails) to
/// report a smaller counterexample.
pub fn check_vec<P>(cfg: PropConfig, len_range: (usize, usize), mut gen_elem: impl FnMut(&mut Pcg64) -> f32, mut prop: P)
where
    P: FnMut(&[f32]) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let mut rng = Pcg64::new(cfg.seed, case as u64);
        let len = len_range.0 + rng.index(len_range.1 - len_range.0 + 1);
        let input: Vec<f32> = (0..len).map(|_| gen_elem(&mut rng)).collect();
        if let Err(first_msg) = prop(&input) {
            // Shrink: binary-halve while the failure persists.
            let mut cur = input.clone();
            let mut msg = first_msg;
            loop {
                if cur.len() <= 1 {
                    break;
                }
                let half = cur.len() / 2;
                let left = &cur[..half];
                let right = &cur[half..];
                if let Err(m) = prop(left) {
                    cur = left.to_vec();
                    msg = m;
                } else if let Err(m) = prop(right) {
                    cur = right.to_vec();
                    msg = m;
                } else {
                    break;
                }
            }
            panic!(
                "vec property failed at case {case} (seed {:#x}): {msg}\nshrunk input ({} elems): {:?}",
                cfg.seed,
                cur.len(),
                &cur[..cur.len().min(32)]
            );
        }
    }
}

/// Generator helpers.
pub mod gen {
    use crate::util::rng::Pcg64;

    /// Uniform float in [lo, hi).
    pub fn f32_in(lo: f32, hi: f32) -> impl FnMut(&mut Pcg64) -> f32 {
        move |rng| rng.range_f32(lo, hi)
    }

    /// Standard normal floats.
    pub fn f32_normal(std: f32) -> impl FnMut(&mut Pcg64) -> f32 {
        move |rng| rng.normal_f32(0.0, std)
    }

    /// "Gradient-like" floats: mixture of small dense noise and occasional
    /// large-magnitude coordinates — stresses the clipping path of
    /// sparsign (Remark 7) and the scale-free invariants.
    pub fn f32_gradient_like() -> impl FnMut(&mut Pcg64) -> f32 {
        move |rng| {
            if rng.bernoulli(0.05) {
                rng.normal_f32(0.0, 10.0)
            } else if rng.bernoulli(0.1) {
                0.0
            } else {
                rng.normal_f32(0.0, 0.1)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            PropConfig::default(),
            |rng| rng.f32(),
            |x| {
                if (0.0..1.0).contains(x) {
                    Ok(())
                } else {
                    Err(format!("out of range: {x}"))
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(
            PropConfig { cases: 16, seed: 1 },
            |rng| rng.f32(),
            |x| if *x < 0.5 { Ok(()) } else { Err("too big".into()) },
        );
    }

    #[test]
    fn vec_property_runs() {
        check_vec(
            PropConfig { cases: 32, seed: 2 },
            (1, 64),
            gen::f32_normal(1.0),
            |v| {
                if v.iter().all(|x| x.is_finite()) {
                    Ok(())
                } else {
                    Err("non-finite".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "shrunk input")]
    fn vec_property_shrinks() {
        check_vec(
            PropConfig { cases: 8, seed: 3 },
            (8, 64),
            gen::f32_in(0.0, 2.0),
            |v| {
                if v.iter().all(|x| *x < 1.9) {
                    Ok(())
                } else {
                    Err("contains large".into())
                }
            },
        );
    }
}
