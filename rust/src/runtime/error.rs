//! Runtime error type — a dependency-free replacement for `anyhow` so the
//! default build carries zero external crates (the `pjrt` feature is the
//! only thing that links against the XLA tree).

use std::fmt;

/// A boxed-string runtime error (artifact discovery, shape validation,
/// PJRT client/compile/execute failures).
#[derive(Debug)]
pub struct RtError(pub String);

impl RtError {
    pub fn msg(s: impl Into<String>) -> Self {
        RtError(s.into())
    }
}

impl fmt::Display for RtError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RtError {}

impl From<std::io::Error> for RtError {
    fn from(e: std::io::Error) -> Self {
        RtError(format!("io: {e}"))
    }
}

impl From<String> for RtError {
    fn from(s: String) -> Self {
        RtError(s)
    }
}

/// Result alias used across the runtime layer.
pub type RtResult<T> = Result<T, RtError>;

/// `ensure!`-style helper: error out with a formatted message unless the
/// condition holds.
macro_rules! rt_ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::runtime::RtError(format!($($arg)+)));
        }
    };
}

/// `anyhow!`-style helper: build an [`RtError`] from a format string.
macro_rules! rt_err {
    ($($arg:tt)+) => {
        $crate::runtime::RtError(format!($($arg)+))
    };
}

pub(crate) use rt_ensure;
pub(crate) use rt_err;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_conversions() {
        let e = RtError::msg("boom");
        assert_eq!(format!("{e}"), "boom");
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: RtError = io.into();
        assert!(format!("{e}").contains("nope"));
        let boxed: Box<dyn std::error::Error> = Box::new(RtError::msg("x"));
        assert_eq!(boxed.to_string(), "x");
    }
}
