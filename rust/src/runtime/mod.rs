//! The PJRT runtime bridge: load the JAX/Pallas models AOT-lowered to HLO
//! text by `python/compile/aot.py`, compile them once on the PJRT CPU
//! client, and execute them from the coordinator's hot path. Python is
//! never on the request path — after `make artifacts` the rust binary is
//! self-contained.
//!
//! * [`Runtime`] — client + executable cache keyed by artifact name.
//! * [`ArtifactRegistry`] — locates `artifacts/*.hlo.txt`, parses
//!   `manifest.txt`, and validates input shapes before execution.
//! * [`HloModel`] — implements [`crate::model::Model`] backed by the
//!   `*_grad` + `*_logits` artifact pair, so the federated engine runs the
//!   L2 JAX graphs (including the fused L1 sparsign variant) without code
//!   changes.
//!
//! ## Dependency gating
//!
//! The PJRT client lives behind the `pjrt` cargo feature because it links
//! against the `xla` crate tree, which is not part of the default
//! (dependency-free) build. With the feature off, an API-identical stub is
//! compiled instead: the literal helpers work on plain in-memory tensors,
//! and [`Runtime::cpu`] / [`HloModel::load`] return a descriptive error so
//! artifact-dependent callers skip at runtime. Everything else in the
//! crate (compressors, coordinator, experiments) is unaffected.

mod error;
mod manifest;

pub use error::{RtError, RtResult};
pub use manifest::{ArtifactRegistry, ArtifactSpec, ShapeSpec};

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{
    literal_f32, literal_i32, literal_u32, scalar_f32, vec_f32, HloModel, Literal,
    Runtime,
};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{
    literal_f32, literal_i32, literal_u32, scalar_f32, vec_f32, HloModel, Literal,
    Runtime,
};
