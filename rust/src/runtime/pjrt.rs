//! The real PJRT-backed runtime (feature `pjrt`): load the JAX/Pallas
//! models AOT-lowered to HLO text by `python/compile/aot.py`, compile them
//! once on the PJRT CPU client, and execute them from the coordinator's
//! hot path. Requires the `xla` crate from the internal registry — see the
//! crate manifest; the default build compiles the API-identical stub in
//! `stub.rs` instead.

use super::error::{rt_ensure, rt_err, RtResult};
use super::manifest::ArtifactRegistry;
use crate::model::{Model, ModelWorkspace};
use crate::util::rng::Pcg64;
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;

/// Literal tensor type (re-exported so callers are mode-agnostic).
pub type Literal = xla::Literal;

/// A loaded PJRT CPU runtime with a compile cache.
pub struct Runtime {
    client: xla::PjRtClient,
    registry: ArtifactRegistry,
    cache: RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Create a CPU runtime over the given artifacts directory
    /// (typically `"artifacts"`).
    pub fn cpu(artifacts_dir: impl AsRef<Path>) -> RtResult<Self> {
        let registry = ArtifactRegistry::open(artifacts_dir.as_ref())?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| rt_err!("PJRT CPU client: {e:?}"))?;
        Ok(Self { client, registry, cache: RefCell::new(HashMap::new()) })
    }

    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact by name (cached).
    pub fn executable(
        &self,
        name: &str,
    ) -> RtResult<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let path = self.registry.hlo_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| rt_err!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| rt_err!("compile {name}: {e:?}"))?;
        let exe = std::rc::Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact on literal inputs, returning the decomposed
    /// output tuple (aot.py lowers with `return_tuple=True`).
    pub fn execute(&self, name: &str, inputs: &[Literal]) -> RtResult<Vec<Literal>> {
        let counts: Vec<i64> =
            inputs.iter().map(|l| l.element_count() as i64).collect();
        self.registry.validate_element_counts(name, &counts)?;
        let exe = self.executable(name)?;
        let result = exe
            .execute::<Literal>(inputs)
            .map_err(|e| rt_err!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| rt_err!("fetch {name} result: {e:?}"))?;
        lit.to_tuple().map_err(|e| rt_err!("untuple {name} result: {e:?}"))
    }
}

/// Build an f32 literal of the given shape from a slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> RtResult<Literal> {
    let n: i64 = dims.iter().product();
    rt_ensure!(n as usize == data.len(), "shape {dims:?} vs len {}", data.len());
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| rt_err!("reshape: {e:?}"))
}

/// Build an i32 literal.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> RtResult<Literal> {
    let n: i64 = dims.iter().product();
    rt_ensure!(n as usize == data.len(), "shape {dims:?} vs len {}", data.len());
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| rt_err!("reshape: {e:?}"))
}

/// Build a u32 literal (threefry keys for the fused sparsign artifacts).
pub fn literal_u32(data: &[u32], dims: &[i64]) -> RtResult<Literal> {
    let n: i64 = dims.iter().product();
    rt_ensure!(n as usize == data.len(), "shape {dims:?} vs len {}", data.len());
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| rt_err!("reshape: {e:?}"))
}

/// Extract a scalar f32 from a literal (shape `[]` or `[1]`).
pub fn scalar_f32(lit: &Literal) -> RtResult<f32> {
    lit.get_first_element::<f32>().map_err(|e| rt_err!("scalar: {e:?}"))
}

/// Extract a Vec<f32>.
pub fn vec_f32(lit: &Literal) -> RtResult<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| rt_err!("to_vec: {e:?}"))
}

/// A [`Model`] backed by AOT-compiled JAX artifacts.
///
/// Uses the `<stem>_grad` artifact for `loss_grad` (fixed batch — the
/// engine must be configured with the artifact's batch size) and
/// `<stem>_logits` for `evaluate` (arbitrary size via padded chunks).
///
/// `Send + Sync`: the compile cache is `Rc`/`RefCell`, so this type is
/// only sound while at most one thread touches it at a time. That
/// invariant is enforced structurally: `Model::serial_only()` returns
/// `true`, which makes the round engine clamp its worker fan-out to a
/// single thread for any `GradientSource` backed by this model — no call
/// site has to remember a `threads` override.
pub struct HloModel {
    runtime: std::rc::Rc<Runtime>,
    stem: String,
    inputs: usize,
    classes: usize,
    dim: usize,
    batch: usize,
    /// Rust twin used only for `init` (identical flat layout — see
    /// `python/tests/test_model.py::test_mlp_dim_matches_rust_layout`).
    init_twin: crate::model::Mlp,
}

// SAFETY: see struct docs — `serial_only()` pins the engine to one
// thread, so the Rc/RefCell cache is never accessed concurrently.
unsafe impl Send for HloModel {}
unsafe impl Sync for HloModel {}

impl HloModel {
    /// Load `<stem>_grad` / `<stem>_logits` from `runtime`'s registry.
    /// `hidden` must match the JAX `MlpSpec` so the parameter layout and
    /// `dim` agree (checked against the manifest).
    pub fn load(
        runtime: std::rc::Rc<Runtime>,
        stem: &str,
        inputs: usize,
        hidden: Vec<usize>,
        classes: usize,
    ) -> RtResult<Self> {
        let grad_name = format!("{stem}_grad");
        let spec = runtime.registry.spec(&grad_name)?;
        rt_ensure!(spec.inputs.len() >= 3, "{grad_name}: expected ≥3 inputs");
        let batch = spec.inputs[1].dims[0] as usize;
        let twin = crate::model::Mlp::new(inputs, hidden, classes);
        let dim = spec.inputs[0].dims[0] as usize;
        rt_ensure!(
            dim == twin.dim(),
            "artifact {grad_name} has {dim} params but the rust spec implies {}",
            twin.dim()
        );
        // Force-compile both executables up front (fail fast, warm cache).
        runtime.executable(&grad_name)?;
        runtime.executable(&format!("{stem}_logits"))?;
        Ok(Self {
            runtime,
            stem: stem.to_string(),
            inputs,
            classes,
            dim,
            batch,
            init_twin: twin,
        })
    }

    /// The batch size baked into the grad artifact.
    pub fn batch(&self) -> usize {
        self.batch
    }

    fn onehot(&self, y: &[usize]) -> Vec<f32> {
        let mut out = vec![0.0f32; y.len() * self.classes];
        for (i, &yi) in y.iter().enumerate() {
            assert!(yi < self.classes, "label {yi} out of range");
            out[i * self.classes + yi] = 1.0;
        }
        out
    }
}

impl Model for HloModel {
    fn dim(&self) -> usize {
        self.dim
    }

    // The workspace is unused here: PJRT owns its device buffers, and the
    // literal round-trips below allocate by necessity (the zero-allocation
    // contract applies to the pure-rust models only).
    fn loss_grad_ws(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[usize],
        grad: &mut [f32],
        _ws: &mut ModelWorkspace,
    ) -> f32 {
        assert_eq!(params.len(), self.dim);
        assert_eq!(
            y.len(),
            self.batch,
            "HLO grad artifact {} requires batch {} (got {}) — configure the \
             engine batch to match",
            self.stem,
            self.batch,
            y.len()
        );
        let name = format!("{}_grad", self.stem);
        let inputs = [
            literal_f32(params, &[self.dim as i64]).unwrap(),
            literal_f32(x, &[self.batch as i64, self.inputs as i64]).unwrap(),
            literal_f32(&self.onehot(y), &[self.batch as i64, self.classes as i64])
                .unwrap(),
        ];
        let out = self
            .runtime
            .execute(&name, &inputs)
            .unwrap_or_else(|e| panic!("HLO execute failed: {e}"));
        let loss = scalar_f32(&out[0]).expect("loss scalar");
        let g = vec_f32(&out[1]).expect("grad vector");
        grad.copy_from_slice(&g);
        loss
    }

    fn evaluate_ws(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[usize],
        _ws: &mut ModelWorkspace,
    ) -> (f64, f64) {
        let n = y.len();
        assert!(n > 0);
        let name = format!("{}_logits", self.stem);
        let p_lit = literal_f32(params, &[self.dim as i64]).unwrap();
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        let mut start = 0usize;
        while start < n {
            let take = (n - start).min(self.batch);
            // Pad the chunk to the artifact batch.
            let mut bx = vec![0.0f32; self.batch * self.inputs];
            bx[..take * self.inputs]
                .copy_from_slice(&x[start * self.inputs..(start + take) * self.inputs]);
            let x_lit =
                literal_f32(&bx, &[self.batch as i64, self.inputs as i64]).unwrap();
            let out = self
                .runtime
                .execute(&name, &[p_lit.clone(), x_lit])
                .unwrap_or_else(|e| panic!("HLO eval failed: {e}"));
            let mut logits = vec_f32(&out[0]).expect("logits");
            crate::util::linalg::softmax_rows(&mut logits, self.batch, self.classes);
            for i in 0..take {
                let yi = y[start + i];
                let row = &logits[i * self.classes..(i + 1) * self.classes];
                loss -= (row[yi].max(1e-12) as f64).ln();
                let argmax = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(j, _)| j)
                    .unwrap();
                if argmax == yi {
                    correct += 1;
                }
            }
            start += take;
        }
        (loss / n as f64, correct as f64 / n as f64)
    }

    fn init(&self, rng: &mut Pcg64) -> Vec<f32> {
        self.init_twin.init(rng)
    }

    fn describe(&self) -> String {
        format!("hlo({}, batch={})", self.stem, self.batch)
    }

    fn serial_only(&self) -> bool {
        true // Rc/RefCell compile cache — see the struct SAFETY note
    }
}
