//! Artifact registry: discovery, manifest parsing, shape validation and
//! staleness checks for the `artifacts/` directory produced by
//! `python/compile/aot.py`.

use super::error::{rt_ensure, rt_err, RtResult};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Parsed dtype + dims of one artifact input, e.g. `f32[64,784]`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShapeSpec {
    pub dtype: String,
    pub dims: Vec<i64>,
}

impl ShapeSpec {
    /// Parse `"float32[64,784]"` / `"uint32[2]"` / `"f32[]"`.
    pub fn parse(s: &str) -> Result<Self, String> {
        let (dtype, rest) = s
            .split_once('[')
            .ok_or_else(|| format!("shape '{s}': missing '['"))?;
        let dims_str = rest
            .strip_suffix(']')
            .ok_or_else(|| format!("shape '{s}': missing ']'"))?;
        let dims = if dims_str.is_empty() {
            Vec::new()
        } else {
            dims_str
                .split(',')
                .map(|d| d.trim().parse::<i64>().map_err(|_| format!("bad dim '{d}'")))
                .collect::<Result<Vec<_>, _>>()?
        };
        Ok(ShapeSpec { dtype: dtype.to_string(), dims })
    }

    pub fn element_count(&self) -> i64 {
        self.dims.iter().product()
    }
}

/// One artifact's manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub inputs: Vec<ShapeSpec>,
}

/// Registry over an artifacts directory.
pub struct ArtifactRegistry {
    dir: PathBuf,
    specs: HashMap<String, ArtifactSpec>,
}

impl ArtifactRegistry {
    /// Open a directory; parses `manifest.txt` if present (artifacts
    /// without a manifest are still loadable, just not shape-validated).
    pub fn open(dir: &Path) -> RtResult<Self> {
        rt_ensure!(
            dir.is_dir(),
            "artifact directory {} does not exist — run `make artifacts`",
            dir.display()
        );
        let mut specs = HashMap::new();
        let manifest = dir.join("manifest.txt");
        if manifest.is_file() {
            let body = std::fs::read_to_string(&manifest)?;
            for (ln, line) in body.lines().enumerate() {
                let line = line.trim();
                if line.is_empty() {
                    continue;
                }
                let spec = Self::parse_line(line)
                    .map_err(|e| rt_err!("manifest line {}: {e}", ln + 1))?;
                specs.insert(spec.name.clone(), spec);
            }
        }
        Ok(Self { dir: dir.to_path_buf(), specs })
    }

    fn parse_line(line: &str) -> Result<ArtifactSpec, String> {
        let (name, ins) = line
            .split_once(" :: ")
            .ok_or_else(|| format!("expected 'name :: inputs', got '{line}'"))?;
        let mut inputs = Vec::new();
        for part in ins.split(';') {
            let (_, shape) = part
                .split_once('=')
                .ok_or_else(|| format!("bad input spec '{part}'"))?;
            inputs.push(ShapeSpec::parse(shape)?);
        }
        Ok(ArtifactSpec { name: name.trim().to_string(), inputs })
    }

    /// Names of all artifacts present on disk.
    pub fn names(&self) -> Vec<String> {
        let mut out: Vec<String> = std::fs::read_dir(&self.dir)
            .into_iter()
            .flatten()
            .flatten()
            .filter_map(|e| {
                let name = e.file_name().to_string_lossy().to_string();
                name.strip_suffix(".hlo.txt").map(|s| s.to_string())
            })
            .collect();
        out.sort();
        out
    }

    /// Path to an artifact's HLO text.
    pub fn hlo_path(&self, name: &str) -> RtResult<PathBuf> {
        let path = self.dir.join(format!("{name}.hlo.txt"));
        rt_ensure!(
            path.is_file(),
            "artifact '{name}' not found at {} — run `make artifacts`",
            path.display()
        );
        Ok(path)
    }

    /// Manifest spec for an artifact.
    pub fn spec(&self, name: &str) -> RtResult<&ArtifactSpec> {
        self.specs
            .get(name)
            .ok_or_else(|| rt_err!("artifact '{name}' missing from manifest.txt"))
    }

    /// Validate input element counts against the manifest (the PJRT layer
    /// enforces dtypes; callers map their literal type to counts so this
    /// module stays dependency-free).
    pub fn validate_element_counts(&self, name: &str, counts: &[i64]) -> RtResult<()> {
        let Some(spec) = self.specs.get(name) else {
            return Ok(()); // unmanifested artifacts skip validation
        };
        rt_ensure!(
            counts.len() == spec.inputs.len(),
            "{name}: expected {} inputs, got {}",
            spec.inputs.len(),
            counts.len()
        );
        for (i, (&got, want)) in counts.iter().zip(&spec.inputs).enumerate() {
            rt_ensure!(
                got == want.element_count(),
                "{name} input {i}: {got} elements, manifest says {} ({:?})",
                want.element_count(),
                want.dims
            );
        }
        Ok(())
    }

    /// True when any artifact is older than any compile-path source file —
    /// the freshness check the launcher prints a warning for.
    pub fn is_stale(&self, python_src_dir: &Path) -> bool {
        let newest_src = walk_mtime(python_src_dir);
        let oldest_artifact = std::fs::read_dir(&self.dir)
            .into_iter()
            .flatten()
            .flatten()
            .filter(|e| e.path().extension().map(|x| x == "txt").unwrap_or(false))
            .filter_map(|e| e.metadata().ok().and_then(|m| m.modified().ok()))
            .min();
        match (newest_src, oldest_artifact) {
            (Some(src), Some(art)) => src > art,
            _ => false,
        }
    }
}

fn walk_mtime(dir: &Path) -> Option<std::time::SystemTime> {
    let mut newest = None;
    let entries = std::fs::read_dir(dir).ok()?;
    for e in entries.flatten() {
        let p = e.path();
        let t = if p.is_dir() {
            walk_mtime(&p)
        } else if p.extension().map(|x| x == "py").unwrap_or(false) {
            e.metadata().ok().and_then(|m| m.modified().ok())
        } else {
            None
        };
        if let Some(t) = t {
            newest = Some(match newest {
                None => t,
                Some(n) if t > n => t,
                Some(n) => n,
            });
        }
    }
    newest
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_parsing() {
        let s = ShapeSpec::parse("float32[64,784]").unwrap();
        assert_eq!(s.dtype, "float32");
        assert_eq!(s.dims, vec![64, 784]);
        assert_eq!(s.element_count(), 64 * 784);
        let scalar = ShapeSpec::parse("f32[]").unwrap();
        assert_eq!(scalar.dims, Vec::<i64>::new());
        assert_eq!(scalar.element_count(), 1);
        assert!(ShapeSpec::parse("f32").is_err());
        assert!(ShapeSpec::parse("f32[a]").is_err());
    }

    #[test]
    fn manifest_line_parsing() {
        let spec = ArtifactRegistry::parse_line(
            "mlp_grad :: in0=float32[235146];in1=float32[64,784];in2=float32[64,10]",
        )
        .unwrap();
        assert_eq!(spec.name, "mlp_grad");
        assert_eq!(spec.inputs.len(), 3);
        assert_eq!(spec.inputs[1].dims, vec![64, 784]);
        assert!(ArtifactRegistry::parse_line("garbage").is_err());
    }

    #[test]
    fn registry_over_temp_dir() {
        let dir = std::env::temp_dir().join(format!("sparsignd-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("foo.hlo.txt"), "HloModule foo").unwrap();
        std::fs::write(dir.join("manifest.txt"), "foo :: in0=float32[4]\n").unwrap();
        let reg = ArtifactRegistry::open(&dir).unwrap();
        assert_eq!(reg.names(), vec!["foo"]);
        assert!(reg.hlo_path("foo").is_ok());
        assert!(reg.hlo_path("bar").is_err());
        assert_eq!(reg.spec("foo").unwrap().inputs[0].dims, vec![4]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_is_error() {
        assert!(ArtifactRegistry::open(Path::new("/nonexistent-sparsignd")).is_err());
    }
}
