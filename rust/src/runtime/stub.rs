//! Dependency-free stand-in for the PJRT runtime, compiled when the `pjrt`
//! feature is off (the default — this sandbox registry carries no `xla`
//! crate). The API is signature-identical to `pjrt.rs`:
//!
//! * the literal helpers are fully functional (plain in-memory tensors
//!   with shape validation), so pure-helper call sites and unit tests
//!   behave the same in both modes;
//! * [`Runtime::cpu`] always returns an error, so every artifact-dependent
//!   path (examples, integration tests, the `artifacts` CLI command, the
//!   PJRT bench section) skips gracefully at runtime instead of failing to
//!   build.

use super::error::{rt_ensure, rt_err, RtResult};
use super::manifest::ArtifactRegistry;
use crate::model::{Model, ModelWorkspace};
use crate::util::rng::Pcg64;
use std::path::Path;

/// In-memory literal tensor: data + shape, no backing device buffer.
#[derive(Clone, Debug)]
pub struct Literal {
    data: LiteralData,
    dims: Vec<i64>,
}

#[derive(Clone, Debug)]
enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
}

impl Literal {
    /// Total number of elements.
    pub fn element_count(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
            LiteralData::U32(v) => v.len(),
        }
    }

    /// Declared shape.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

const DISABLED: &str = "built without the `pjrt` feature — the PJRT/XLA runtime is \
                        unavailable; rebuild with `--features pjrt` (requires the \
                        vendored `xla` crate) to execute AOT artifacts";

/// Stub runtime: construction always fails with a clear message.
pub struct Runtime {
    registry: ArtifactRegistry,
}

impl Runtime {
    pub fn cpu(artifacts_dir: impl AsRef<Path>) -> RtResult<Self> {
        // Validate the directory anyway so error messages stay useful.
        let _registry = ArtifactRegistry::open(artifacts_dir.as_ref())?;
        Err(rt_err!("{DISABLED}"))
    }

    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }

    pub fn platform(&self) -> String {
        "stub (pjrt feature disabled)".into()
    }

    pub fn execute(&self, _name: &str, _inputs: &[Literal]) -> RtResult<Vec<Literal>> {
        Err(rt_err!("{DISABLED}"))
    }

    /// Compile-cache lookup; always unavailable in the stub.
    pub fn executable(&self, _name: &str) -> RtResult<()> {
        Err(rt_err!("{DISABLED}"))
    }
}

/// Build an f32 literal of the given shape from a slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> RtResult<Literal> {
    let n: i64 = dims.iter().product();
    rt_ensure!(n as usize == data.len(), "shape {dims:?} vs len {}", data.len());
    Ok(Literal { data: LiteralData::F32(data.to_vec()), dims: dims.to_vec() })
}

/// Build an i32 literal.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> RtResult<Literal> {
    let n: i64 = dims.iter().product();
    rt_ensure!(n as usize == data.len(), "shape {dims:?} vs len {}", data.len());
    Ok(Literal { data: LiteralData::I32(data.to_vec()), dims: dims.to_vec() })
}

/// Build a u32 literal.
pub fn literal_u32(data: &[u32], dims: &[i64]) -> RtResult<Literal> {
    let n: i64 = dims.iter().product();
    rt_ensure!(n as usize == data.len(), "shape {dims:?} vs len {}", data.len());
    Ok(Literal { data: LiteralData::U32(data.to_vec()), dims: dims.to_vec() })
}

/// Extract a scalar f32 from a literal (shape `[]` or `[1]`; the stub
/// returns the first element, matching the PJRT helper).
pub fn scalar_f32(lit: &Literal) -> RtResult<f32> {
    match &lit.data {
        LiteralData::F32(v) if !v.is_empty() => Ok(v[0]),
        LiteralData::F32(_) => Err(rt_err!("scalar: empty literal")),
        _ => Err(rt_err!("scalar: literal is not f32")),
    }
}

/// Extract a Vec<f32>.
pub fn vec_f32(lit: &Literal) -> RtResult<Vec<f32>> {
    match &lit.data {
        LiteralData::F32(v) => Ok(v.clone()),
        _ => Err(rt_err!("to_vec: literal is not f32")),
    }
}

/// Stub HLO-backed model: [`HloModel::load`] always errors (there is no
/// executor), so instances cannot exist; the trait impl keeps call sites
/// compiling unchanged.
pub struct HloModel {
    never: std::convert::Infallible,
}

impl HloModel {
    pub fn load(
        _runtime: std::rc::Rc<Runtime>,
        _stem: &str,
        _inputs: usize,
        _hidden: Vec<usize>,
        _classes: usize,
    ) -> RtResult<Self> {
        Err(rt_err!("{DISABLED}"))
    }

    pub fn batch(&self) -> usize {
        match self.never {}
    }
}

impl Model for HloModel {
    fn dim(&self) -> usize {
        match self.never {}
    }

    fn loss_grad_ws(
        &self,
        _p: &[f32],
        _x: &[f32],
        _y: &[usize],
        _g: &mut [f32],
        _ws: &mut ModelWorkspace,
    ) -> f32 {
        match self.never {}
    }

    fn evaluate_ws(
        &self,
        _p: &[f32],
        _x: &[f32],
        _y: &[usize],
        _ws: &mut ModelWorkspace,
    ) -> (f64, f64) {
        match self.never {}
    }

    fn init(&self, _rng: &mut Pcg64) -> Vec<f32> {
        match self.never {}
    }

    fn describe(&self) -> String {
        match self.never {}
    }

    fn serial_only(&self) -> bool {
        match self.never {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_helpers_validate_shape() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).is_ok());
        assert!(literal_i32(&[1, 2], &[2]).is_ok());
        assert!(literal_u32(&[1, 2], &[1]).is_err());
    }

    #[test]
    fn scalar_and_vec_roundtrip() {
        let lit = literal_f32(&[3.5, 4.5], &[2]).unwrap();
        assert_eq!(vec_f32(&lit).unwrap(), vec![3.5, 4.5]);
        assert_eq!(scalar_f32(&lit).unwrap(), 3.5);
        assert_eq!(lit.element_count(), 2);
        assert_eq!(lit.dims(), &[2]);
    }

    #[test]
    fn runtime_construction_reports_disabled() {
        // Any directory (existing or not) must fail without panicking.
        let err = Runtime::cpu("/nonexistent-sparsignd").unwrap_err();
        assert!(!format!("{err}").is_empty());
        let dir = std::env::temp_dir().join(format!("sparsignd-stub-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let err = Runtime::cpu(&dir).unwrap_err();
        assert!(format!("{err}").contains("pjrt"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
