//! `sparsignd` — the launcher.
//!
//! ```text
//! sparsignd train     [--rounds N] [--alpha A] [--workers M] [--lr X] …
//! sparsignd tables    [--preset fast|paper] [--only table1[,table2…]]
//! sparsignd fig1      [--rounds N] [--lr X] [--csv out.csv]
//! sparsignd fig2      [--rounds N] [--lr X] [--csv out.csv]
//! sparsignd theory    [--trials N]
//! sparsignd dataset   convert --out F.sgds --clients M --alpha A --seed S
//!                     (--synthetic fmnist|cifar10|cifar100 [--scale F] [--dim D]
//!                      | --format idx --images F --labels F --test-images F --test-labels F
//!                      | --format cifar10|cifar100 --bins f1,f2,… --test-bins f)
//! sparsignd dataset   info --data F.sgds
//! sparsignd parity    --data F.sgds --dataset fmnist|cifar10|cifar100 [--rounds N]
//!                     [--algs substr,…] [--hidden h1,h2] [--trials N] [--min-acc X]
//!                     [--csv out.csv]
//! sparsignd serve     [--addr EP] [--clients M] [--rounds N] [--deadline-ms D]
//!                     [--shards N] [--snapshot F [--snapshot-every K]] [--resume F]
//!                     [--drain-after N] [--endpoint-file F] [--history-json F]
//!                     [--metrics-addr EP] [--metrics-linger-ms D]
//!                     [--attack SPEC] [--selection legacy|committed] …
//! sparsignd fleet     [--clients M] [--rounds N] [--transport tcp|uds]
//!                     [--shards N | --via-shards] [--connect EP | --connect-file F]
//!                     [--reconnect-secs S] [--attack SPEC]
//!                     [--selection legacy|committed] …
//! sparsignd benchdiff --baseline F --fresh F [--tolerance T]
//! sparsignd artifacts
//! ```
//!
//! Every subcommand parses its flags through the typed structs in
//! [`sparsignd::cli::opts`]: unknown flags and unparseable values are
//! rejected with a typed error (exit 2), never silently defaulted.
//!
//! Everything the launcher does is also available as a library call; the
//! examples/ binaries show the embedded usage.

use sparsignd::cli::opts::{
    self, CliError, FleetMode, FleetOpts, ParityOpts, ServeOpts, ShardOpts, ShardUpstream,
    SoakOpts, TrainOpts,
};
use sparsignd::cli::ArgMap;
use sparsignd::config::ExperimentConfig;
use sparsignd::coordinator::{
    Algorithm, AttackPlan, ClassifierEnv, GradientSource, RunHistory, TrainingRun,
};
use sparsignd::data::{
    load_cifar_binary, load_idx_pair, write_store, Dataset, DirichletPartitioner, ShardStore,
    SyntheticSpec, SyntheticTask,
};
use sparsignd::experiments;
use sparsignd::metrics::write_csv;
use sparsignd::model::ModelKind;
use sparsignd::net;
use sparsignd::optim::LrSchedule;
use sparsignd::snapshot::{CoordinatorSnapshot, SnapshotPolicy};
use sparsignd::util::rng::Pcg64;

fn main() {
    let args = ArgMap::from_env();
    let code = match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("tables") => cmd_tables(&args),
        Some("fig1") => cmd_fig(&args, true),
        Some("fig2") => cmd_fig(&args, false),
        Some("theory") => cmd_theory(&args),
        Some("dataset") => cmd_dataset(&args),
        Some("parity") => cmd_parity(&args),
        Some("serve") => cmd_serve(&args),
        Some("shard") => cmd_shard(&args),
        Some("fleet") => cmd_fleet(&args),
        Some("soak") => cmd_soak(&args),
        Some("benchdiff") => cmd_benchdiff(&args),
        Some("artifacts") => cmd_artifacts(&args),
        Some(other) => {
            eprintln!("unknown subcommand '{other}'");
            usage();
            2
        }
        None => {
            usage();
            0
        }
    };
    std::process::exit(code);
}

/// Typed CLI rejection → operator message + exit 2.
fn cli_err(e: CliError) -> i32 {
    eprintln!("{e}");
    2
}

fn usage() {
    println!(
        "sparsignd — magnitude-aware sparsified signSGD (SPARSIGNSGD / EF-SPARSIGNSGD)\n\
         \n\
         subcommands:\n\
         \x20 train      run the fast-preset experiment (override via --rounds/--alpha/…)\n\
         \x20 tables     regenerate the paper's tables (--preset fast|paper, --only …;\n\
         \x20            --only attacks for the Byzantine convergence sweep)\n\
         \x20 fig1       Rosenbrock wrong-aggregation figure (sign vs sparsign)\n\
         \x20 fig2       Rosenbrock worker-sampling figure\n\
         \x20 theory     Theorem 1 Monte-Carlo bound check\n\
         \x20 dataset    convert — build a .sgds store (mmap-ready, CRC-guarded,\n\
         \x20            embedded Dirichlet(α) partition) from --synthetic\n\
         \x20            fmnist|cifar10|cifar100 or --format idx|cifar10|cifar100\n\
         \x20            downloads; info — print an existing store's header\n\
         \x20 parity     paper-parity accuracy-vs-communication sweep streamed\n\
         \x20            from --data F.sgds (--dataset picks the paper protocol,\n\
         \x20            --algs trims the roster, --hidden h1,h2 swaps in an MLP,\n\
         \x20            --min-acc X exits 1 below the accuracy floor)\n\
         \x20 serve      run the federation coordinator on a TCP/UDS endpoint\n\
         \x20            (--shards N adds in-process aggregator shards, endpoint\n\
         \x20            file gains one shard line each; --snapshot/--resume/\n\
         \x20            --drain-after for elastic runs; exit 3 = drained;\n\
         \x20            --event-log F appends structured JSONL, --heal-attempts K\n\
         \x20            re-opens any round that closes below full coverage;\n\
         \x20            --metrics-addr EP serves Prometheus GET /metrics and\n\
         \x20            GET /healthz from the reactor thread — in-process shards\n\
         \x20            get derived scrape ports, the endpoint file gains\n\
         \x20            '# metrics …' comment lines, and --metrics-linger-ms D\n\
         \x20            keeps answering scrapes for D ms after the final round)\n\
         \x20 shard      run one aggregator shard as its own process:\n\
         \x20            --index I --shard-count K --listen EP, upstream from\n\
         \x20            --connect EP or --connect-file F (line 0, re-read with\n\
         \x20            --reconnect-secs backoff on every upstream loss);\n\
         \x20            --publish-file F writes the resolved listen endpoint;\n\
         \x20            --metrics-addr EP exposes the shard's own scrape port\n\
         \x20 fleet      drive a client fleet; default: loopback run diffed\n\
         \x20            against the in-process engine (exit 1 on mismatch;\n\
         \x20            --shards N routes it through an aggregation tree);\n\
         \x20            --connect/--connect-file agents reconnect with backoff,\n\
         \x20            --via-shards splits sub-fleets over the shard lines,\n\
         \x20            --shard-line I serves slice I of --shard-count K\n\
         \x20 soak       churn soak: fork a serve/shard/fleet process tree,\n\
         \x20            kill+respawn children on a seeded --faults schedule,\n\
         \x20            scrape the root's /metrics across respawns, exit 1\n\
         \x20            unless the history is bit-identical to an\n\
         \x20            uninterrupted reference run of the same flags\n\
         \x20 benchdiff  diff a fresh BENCH_*.json against the committed\n\
         \x20            baseline; exit 1 on >tolerance throughput regression\n\
         \x20 artifacts  list AOT artifacts + staleness\n\
         \n\
         train/serve/fleet/shard/soak also accept --data F.sgds: the run streams\n\
         the store's dataset and embedded partition instead of regenerating a\n\
         synthetic task (--dim/--classes/--alpha are then pinned by the store;\n\
         --hidden h1,h2 swaps the linear model for an MLP)"
    );
}

fn apply_cli_overrides(cfg: &mut ExperimentConfig, t: &TrainOpts) -> Result<(), String> {
    for (k, v) in &t.overrides {
        cfg.apply_override(k, v)?;
    }
    cfg.validate()
}

fn cmd_train(args: &ArgMap) -> i32 {
    let topts = match TrainOpts::from_args(args) {
        Ok(t) => t,
        Err(e) => return cli_err(e),
    };
    let mut cfg = ExperimentConfig::fast_preset();
    if let Some(path) = &topts.config {
        let body = match std::fs::read_to_string(path) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("config {path}: {e}");
                return 2;
            }
        };
        if let Err(e) = cfg.apply_file(&body) {
            eprintln!("config {path}: {e}");
            return 2;
        }
    }
    if let Err(e) = apply_cli_overrides(&mut cfg, &topts) {
        eprintln!("{e}");
        return 2;
    }
    let report = if let Some(path) = &topts.data {
        // Store-backed run: the dataset, partition and heterogeneity are
        // pinned by the .sgds file; only model init and batch sampling
        // vary across seeds.
        let store = match ShardStore::open(std::path::Path::new(path)) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("--data {path}: {e}");
                return 2;
            }
        };
        cfg.model = store_model(&store, topts.hidden.clone());
        cfg.alpha = store.info().alpha;
        cfg.workers = store.clients();
        let model = cfg.model.clone();
        let batch = cfg.batch;
        experiments::run_classification_with(&cfg, &|_seed| {
            ClassifierEnv::from_store(&store, model.build(), batch)
        })
    } else {
        experiments::run_classification(&cfg)
    };
    println!("{}", report.table());
    println!(
        "partition skew (mean max class fraction): {:.3}",
        report.mean_max_class_fraction
    );
    0
}

fn cmd_tables(args: &ArgMap) -> i32 {
    if let Err(e) = opts::check_known(args, "tables", &["preset", "only"]) {
        return cli_err(e);
    }
    let paper = args.get_str("preset").map(|p| p == "paper").unwrap_or(false);
    let only: Option<Vec<String>> = args
        .get_str("only")
        .map(|s| s.split(',').map(|x| x.trim().to_string()).collect());
    let want = |name: &str| only.as_ref().map(|o| o.iter().any(|x| x == name)).unwrap_or(true);

    if want("table1") {
        println!("{}", experiments::run_classification(&experiments::table1_config(paper)).table());
    }
    if want("table2") {
        println!("{}", experiments::run_classification(&experiments::table2_config(paper)).table());
    }
    if want("table3") {
        println!("{}", experiments::run_classification(&experiments::table3_config(paper)).table());
    }
    if want("tables4_7") {
        for cfg in experiments::tables4_7_configs(paper, &[0.1, 0.3, 0.6, 1.0]) {
            println!("{}", experiments::run_classification(&cfg).table());
        }
    }
    // Not part of the default sweep (it is a robustness suite, not a
    // paper table): opt in with --only attacks.
    if only.as_ref().map(|o| o.iter().any(|x| x == "attacks")).unwrap_or(false) {
        for cfg in experiments::attack_sweep_configs(paper) {
            println!("{}", experiments::run_classification(&cfg).table());
        }
    }
    0
}

fn cmd_fig(args: &ArgMap, fig1: bool) -> i32 {
    let name = if fig1 { "fig1" } else { "fig2" };
    if let Err(e) = opts::check_known(args, name, &["rounds", "lr", "seed", "csv"]) {
        return cli_err(e);
    }
    let rounds = args.get::<usize>("rounds", 3_000);
    let lr = args.get::<f64>("lr", 0.01);
    let seed = args.get::<u64>("seed", 7);
    let series = if fig1 {
        experiments::run_fig1(rounds, lr, seed)
    } else {
        experiments::run_fig2(rounds, lr, seed)
    };
    println!(
        "## Fig. {} — Rosenbrock, M=100, 80 sign-flipped workers (eq. 11)",
        if fig1 { 1 } else { 2 }
    );
    for s in &series {
        println!(
            "  {:<28} mean wrong-aggregation {:.3}   F(start) {:>8.2} → F(end) {:>10.2}",
            s.label,
            s.mean_wrong_agg(),
            s.fvalue.first().unwrap_or(&f64::NAN),
            s.final_value()
        );
    }
    if let Some(path) = args.get_str("csv") {
        let mut rows = Vec::new();
        for (t, _) in series[0].fvalue.iter().enumerate() {
            let mut row = vec![t.to_string()];
            for s in &series {
                row.push(format!("{:.6}", s.wrong_agg[t]));
                row.push(format!("{:.6}", s.fvalue[t]));
            }
            rows.push(row);
        }
        let mut headers = vec!["round".to_string()];
        for s in &series {
            headers.push(format!("{} wrong_agg", s.label));
            headers.push(format!("{} F", s.label));
        }
        let h: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        if let Err(e) = write_csv(path, &h, &rows) {
            eprintln!("csv {path}: {e}");
            return 1;
        }
        println!("wrote {path}");
    }
    0
}

fn cmd_theory(args: &ArgMap) -> i32 {
    if let Err(e) = opts::check_known(args, "theory", &["trials"]) {
        return cli_err(e);
    }
    let trials = args.get::<usize>("trials", 20_000);
    let checks = experiments::theory::sweep(
        &[20, 50, 100, 200, 500],
        &[0.05, 0.1, 0.2, 0.5],
        0.8,
        trials,
        3,
    );
    println!("## Theorem 1 bound check (80% sign-flipped scalars, {trials} trials)");
    println!("{:>5} {:>6} {:>9} {:>9} {:>11} {:>11}", "M", "B", "p_bar", "q_bar", "empirical", "bound");
    let mut ok = true;
    for c in checks {
        let pass = c.empirical <= c.bound + 0.02;
        ok &= pass;
        println!(
            "{:>5} {:>6} {:>9.4} {:>9.4} {:>11.4} {:>11.4} {}",
            c.m,
            c.budget,
            c.p_bar,
            c.q_bar,
            c.empirical,
            c.bound,
            if pass { "" } else { "VIOLATED" }
        );
    }
    if ok {
        0
    } else {
        1
    }
}

/// Model for a store-backed run: linear softmax unless `--hidden` widths
/// were given (input/class dims always come from the store).
fn store_model(store: &ShardStore, hidden: Vec<usize>) -> ModelKind {
    if hidden.is_empty() {
        ModelKind::Linear { inputs: store.dim(), classes: store.classes() }
    } else {
        ModelKind::Mlp { inputs: store.dim(), hidden, classes: store.classes() }
    }
}

const DATASET_FLAGS: &[&str] = &[
    "data",
    "out",
    "clients",
    "alpha",
    "seed",
    "synthetic",
    "scale",
    "dim",
    "classes",
    "format",
    "images",
    "labels",
    "test-images",
    "test-labels",
    "bins",
    "test-bins",
];

/// `dataset convert|info` — build or inspect an `.sgds` store.
fn cmd_dataset(args: &ArgMap) -> i32 {
    if let Err(e) = opts::check_known(args, "dataset", DATASET_FLAGS) {
        return cli_err(e);
    }
    match args.positional.first().map(|s| s.as_str()) {
        Some("info") => {
            let Some(path) = args.get_str("data") else {
                eprintln!("usage: dataset info --data F.sgds");
                return 2;
            };
            match ShardStore::open(std::path::Path::new(path)) {
                Ok(store) => {
                    println!("{path}: {}", store.info().summary());
                    0
                }
                Err(e) => {
                    eprintln!("{path}: {e}");
                    1
                }
            }
        }
        Some("convert") => cmd_dataset_convert(args),
        _ => {
            eprintln!("usage: dataset convert|info … (run `sparsignd` for the flag list)");
            2
        }
    }
}

/// Load the (train, test) pair a `dataset convert` invocation describes.
fn convert_sources(args: &ArgMap) -> Result<(Dataset, Dataset), String> {
    if let Some(name) = args.get_str("synthetic") {
        let mut spec = match name {
            "fmnist" => SyntheticSpec::fmnist_like(),
            "cifar10" => SyntheticSpec::cifar10_like(),
            "cifar100" => SyntheticSpec::cifar100_like(),
            other => {
                return Err(format!("unknown --synthetic '{other}' (fmnist|cifar10|cifar100)"))
            }
        };
        spec = spec.scaled(args.get::<f64>("scale", 1.0));
        if let Some(dim) = args.get_str("dim") {
            spec = spec.with_dim(dim.parse().map_err(|_| format!("--dim: bad value '{dim}'"))?);
        }
        // Same seed-salt convention as the launcher's synthetic path.
        let task = SyntheticTask::generate(spec, args.get::<u64>("seed", 7) ^ 0x5e7);
        return Ok((task.train, task.test));
    }
    let need = |k: &str| args.get_str(k).ok_or_else(|| format!("missing --{k}"));
    match args.str_or("format", "") {
        "idx" => {
            let classes = args.get::<usize>("classes", 10);
            let pair = |img: &str, lbl: &str| -> Result<Dataset, String> {
                load_idx_pair(std::path::Path::new(img), std::path::Path::new(lbl), classes)
                    .map_err(|e| format!("{img}: {e}"))
            };
            let train = pair(need("images")?, need("labels")?)?;
            let test = pair(need("test-images")?, need("test-labels")?)?;
            Ok((train, test))
        }
        fmt @ ("cifar10" | "cifar100") => {
            let (classes, label_bytes) = if fmt == "cifar10" { (10, 1) } else { (100, 2) };
            let load = |spec: &str, tag: &str| -> Result<Dataset, String> {
                let paths: Vec<std::path::PathBuf> = spec
                    .split(',')
                    .map(|s| s.trim())
                    .filter(|s| !s.is_empty())
                    .map(std::path::PathBuf::from)
                    .collect();
                let refs: Vec<&std::path::Path> = paths.iter().map(|p| p.as_path()).collect();
                load_cifar_binary(&refs, classes, label_bytes).map_err(|e| format!("{tag}: {e}"))
            };
            let train = load(need("bins")?, "train bins")?;
            let test = load(need("test-bins")?, "test bins")?;
            Ok((train, test))
        }
        "" => Err("need --synthetic NAME or --format idx|cifar10|cifar100".into()),
        other => Err(format!("unknown --format '{other}'")),
    }
}

fn cmd_dataset_convert(args: &ArgMap) -> i32 {
    let Some(out) = args.get_str("out") else {
        eprintln!("dataset convert needs --out F.sgds");
        return 2;
    };
    let clients = args.get::<usize>("clients", 100);
    let alpha = args.get::<f64>("alpha", 0.5);
    let seed = args.get::<u64>("seed", 7);
    if clients == 0 {
        eprintln!("--clients must be positive");
        return 2;
    }
    let (train, test) = match convert_sources(args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if train.len() < clients {
        eprintln!("{} train rows cannot give every one of {clients} clients data", train.len());
        return 2;
    }
    // `partition_exact` (not `partition`): a store is a long-lived
    // artifact, so every client shard is guaranteed non-empty.
    let mut rng = Pcg64::seed_from(seed ^ 0x9a57);
    let fed = DirichletPartitioner { alpha, workers: clients }.partition_exact(&train, &mut rng);
    match write_store(std::path::Path::new(out), &train, &test, &fed, alpha, seed) {
        Ok(_hash) => match ShardStore::open(std::path::Path::new(out)) {
            Ok(store) => {
                println!("wrote {out}: {}", store.info().summary());
                0
            }
            Err(e) => {
                eprintln!("reopen {out}: {e}");
                1
            }
        },
        Err(e) => {
            eprintln!("write {out}: {e}");
            1
        }
    }
}

/// `parity` — the paper-parity sweep over a streamed `.sgds` store.
fn cmd_parity(args: &ArgMap) -> i32 {
    let p = match ParityOpts::from_args(args) {
        Ok(p) => p,
        Err(e) => return cli_err(e),
    };
    let store = match ShardStore::open(std::path::Path::new(&p.data)) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("--data {}: {e}", p.data);
            return 2;
        }
    };
    let mut cfg = match experiments::parity_config(&p.dataset) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if let Some(algs) = &p.algs {
        let pats: Vec<&str> = algs.iter().map(|s| s.as_str()).collect();
        if let Err(e) = experiments::retain_algorithms(&mut cfg, &pats) {
            eprintln!("--algs: {e}");
            return 2;
        }
    }
    if let Some(rounds) = p.rounds {
        cfg.rounds = rounds;
    }
    if let Some(batch) = p.batch {
        cfg.batch = batch;
    }
    if let Some(eval_every) = p.eval_every {
        cfg.eval_every = eval_every;
    }
    if let Some(trials) = p.trials {
        cfg.seeds = (0..trials as u64).collect();
    }
    let out = experiments::run_parity(&store, cfg, &p.dataset, &p.hidden);
    println!("{}", out.report.table());
    println!("{}", out.parity_table);
    if let Some(csv) = &p.csv {
        let mut rows = Vec::new();
        for (label, series) in &out.report.series {
            for (round, acc, bits) in series {
                rows.push(vec![
                    label.clone(),
                    round.to_string(),
                    format!("{acc:.6}"),
                    format!("{bits:.0}"),
                ]);
            }
        }
        let headers = ["algorithm", "round", "acc", "cum_uplink_bits"];
        if let Err(e) = write_csv(csv, &headers, &rows) {
            eprintln!("csv {csv}: {e}");
            return 1;
        }
        println!("wrote {csv}");
    }
    if out.best_acc < p.min_acc {
        eprintln!("best final accuracy {:.4} is below --min-acc {}", out.best_acc, p.min_acc);
        return 1;
    }
    0
}

/// Shared `serve`/`fleet` run shape: both sides of a distributed run
/// must build it from the same flags (the dataset, partition and init
/// are all derived from `--seed`, or pinned by a shared `--data` store).
struct NetSetup {
    env: ClassifierEnv,
    run: TrainingRun,
    init: Vec<f32>,
}

fn net_setup(o: &opts::NetRunOpts) -> Result<NetSetup, String> {
    let env = if let Some(path) = &o.data {
        // Store-backed run: the dataset and partition are pinned by the
        // .sgds file, whose content hash lands in the environment
        // fingerprint — a fleet holding a different store (different
        // download, different --alpha conversion) is refused at
        // rendezvous instead of silently training on drifted data.
        // (The shape-flag conflict was already rejected by NetRunOpts.)
        let store = ShardStore::open(std::path::Path::new(path))
            .map_err(|e| format!("--data {path}: {e}"))?;
        if o.explicit_clients && o.clients != store.clients() {
            return Err(format!(
                "--clients {} disagrees with the store's {} client shards \
                 (drop the flag or rebuild the store)",
                o.clients,
                store.clients()
            ));
        }
        let model = store_model(&store, o.hidden.clone());
        ClassifierEnv::from_store(&store, model.build(), o.batch)
    } else {
        let task = SyntheticTask::generate(
            SyntheticSpec {
                dim: o.dim,
                classes: o.classes,
                modes: 1,
                separation: 1.8,
                noise: 0.25,
                label_noise: 0.0,
                train: (o.clients * o.batch * 4).max(512),
                test: (o.clients * o.batch).max(256),
            },
            o.seed ^ 0x5e7,
        );
        let mut rng = Pcg64::seed_from(o.seed ^ 0x9a57);
        let fed = DirichletPartitioner { alpha: o.alpha, workers: o.clients }
            .partition(&task.train, &mut rng);
        ClassifierEnv::new(
            ModelKind::Linear { inputs: o.dim, classes: o.classes }.build(),
            task.train,
            task.test,
            fed,
            o.batch,
        )
    };
    // The attack plan's population is the served cohort — for a store
    // run that is the store's client count, not the --clients default.
    let clients = env.fed.workers();
    let mut init_rng = Pcg64::seed_from(o.seed ^ 0x1417);
    let init = env.init_params(&mut init_rng);

    let mut run = TrainingRun::new(
        Algorithm::CompressedGd {
            compressor: o.compressor.clone(),
            aggregation: o.aggregation,
        },
        LrSchedule::Const { lr: o.lr },
        o.rounds,
    );
    run.participation = o.participation;
    run.eval_every = o.eval_every;
    run.seed = o.seed;
    // Byzantine knobs. Both sides of a distributed run derive the same
    // plan from the same flags; the coordinator needs it for its
    // config-fingerprint and the in-process diff, the fleet to enact it.
    if let Some(spec) = &o.attack {
        run.attack = Some(AttackPlan::parse(spec, clients, o.seed)?);
    }
    run.selection = o.selection;
    Ok(NetSetup { env, run, init })
}

/// Field-exact `RunHistory` comparison (the loopback acceptance gate).
fn diff_histories(a: &RunHistory, b: &RunHistory) -> Result<(), String> {
    if a.final_params != b.final_params {
        return Err("final params differ".into());
    }
    if a.reports.len() != b.reports.len() {
        return Err(format!("round counts differ: {} vs {}", a.reports.len(), b.reports.len()));
    }
    for (ra, rb) in a.reports.iter().zip(&b.reports) {
        let same = ra.train_loss == rb.train_loss
            && ra.uplink_bits == rb.uplink_bits
            && ra.downlink_bits == rb.downlink_bits
            && ra.cum_uplink_bits == rb.cum_uplink_bits
            && ra.eval == rb.eval
            && ra.lr == rb.lr;
        if !same {
            return Err(format!("round {} reports differ", ra.round));
        }
    }
    if a.ledger.total_uplink() != b.ledger.total_uplink() {
        return Err("ledger uplink totals differ".into());
    }
    Ok(())
}

/// Publish the resolved endpoints atomically (write-temp + rename) so a
/// fleet polling the file never reads a torn layout. Line 0 is the root
/// coordinator; with `--shards N`, lines `1..=N` are the shard
/// endpoints in shard order (`fleet --via-shards` maps line `1 + i` to
/// worker slice `chunk_bounds(m, N, i)`). Metrics scrape endpoints ride
/// along as trailing `# metrics <who> <ep>` comment lines — *after*
/// every endpoint line, so line-indexed readers are unaffected.
fn write_endpoint_file(
    path: &str,
    eps: &[net::Endpoint],
    comments: &[String],
) -> std::io::Result<()> {
    let tmp = format!("{path}.tmp");
    let mut body = String::new();
    for ep in eps {
        body.push_str(&format!("{ep}\n"));
    }
    for c in comments {
        body.push_str(&format!("{c}\n"));
    }
    std::fs::write(&tmp, body)?;
    std::fs::rename(&tmp, path)
}

/// A listen endpoint for in-process shard `i`, in the root's transport
/// family: an ephemeral TCP port on the root's interface, or the root's
/// socket path suffixed per shard. Also used to derive per-shard
/// metrics scrape endpoints from the root's `--metrics-addr`.
fn shard_listen_endpoint(root: &net::Endpoint, i: usize) -> net::Endpoint {
    #[cfg(not(unix))]
    let _ = i;
    match root {
        net::Endpoint::Tcp(addr) => {
            let host = addr.rsplit_once(':').map(|(h, _)| h).unwrap_or("127.0.0.1");
            net::Endpoint::Tcp(format!("{host}:0"))
        }
        #[cfg(unix)]
        net::Endpoint::Uds(path) => {
            net::Endpoint::Uds(std::path::PathBuf::from(format!("{}.shard{i}", path.display())))
        }
    }
}

fn cmd_serve(args: &ArgMap) -> i32 {
    let so = match ServeOpts::from_args(args) {
        Ok(s) => s,
        Err(e) => return cli_err(e),
    };
    let setup = match net_setup(&so.run) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let mut opts = net::ServeOptions::new(so.addr.clone());
    opts.round_deadline = so.round_deadline;
    opts.rendezvous_timeout = so.rendezvous_timeout;
    opts.drain_after = so.drain_after;
    if let Some((path, every)) = &so.snapshot {
        opts.snapshot = Some(SnapshotPolicy::every(path.as_str(), *every));
    }
    // Structured JSONL event log. A resumed coordinator appends (the
    // soak supervisor reads one continuous log across restarts); a
    // fresh one truncates.
    if let Some(path) = &so.event_log {
        let p = std::path::Path::new(path);
        let log = if so.resume.is_some() {
            net::EventLog::append(p)
        } else {
            net::EventLog::create(p)
        };
        match log {
            Ok(l) => opts.event_log = Some(std::sync::Arc::new(l)),
            Err(e) => {
                eprintln!("event-log {path}: {e}");
                return 1;
            }
        }
    }
    // Strict self-healing: re-open any round that closes below full
    // coverage, up to K attempts per round. 0 (default) keeps the
    // legacy policy (re-open only fully-empty rounds).
    opts.heal_attempts = so.heal_attempts;
    if let Some(plan) = &so.run.faults {
        let inj = plan.injector(net::FaultRole::Root);
        if !inj.is_empty() {
            opts.faults = Some(inj);
        }
    }
    // Live observability plane: the reactor answers GET /metrics and
    // GET /healthz on this second listener; the linger window keeps it
    // scrapeable after Fin so end-of-run totals are observable.
    opts.metrics_addr = so.metrics_addr.clone();
    opts.metrics_linger = so.metrics_linger;
    // Mix the constructed environment's structural hash into snapshot
    // fingerprints so a resume refuses a dataset rebuilt with different
    // --alpha/--batch/--dim flags (same d/M, different data).
    opts.env_fingerprint = setup.env.env_fingerprint();
    if let Some(path) = &so.resume {
        match CoordinatorSnapshot::load(std::path::Path::new(path)) {
            Ok(snap) => {
                println!("resuming from {path} (round {})", snap.next_round());
                opts.resume = Some(snap);
            }
            Err(e) => {
                eprintln!("resume {path}: {e}");
                return 2;
            }
        }
    }
    // Shard options mirror the root's knobs; captured here because
    // `bind` consumes `opts`. Shards get 3/4 of the root deadline so
    // their merged frame lands before the root closes the round.
    let root_deadline = opts.round_deadline;
    let rendezvous = opts.rendezvous_timeout;
    let max_payload = opts.max_payload;
    let env_fp = opts.env_fingerprint;
    let coordinator = match net::NetCoordinator::bind(opts) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("bind: {e}");
            return 1;
        }
    };
    let NetSetup { env, run, init } = setup;
    let m = env.fed.workers();
    let d = init.len();
    let root_ep = coordinator.local_endpoint().clone();
    let shards_n = so.shards;
    let mut shard_coords = Vec::new();
    for i in 0..shards_n.min(m) {
        let (lo, hi) = sparsignd::coordinator::chunk_bounds(m, shards_n.min(m), i);
        let mut sopts = net::ShardOptions::new(
            root_ep.clone(),
            shard_listen_endpoint(&root_ep, i),
            lo,
            hi,
        );
        sopts.round_deadline = root_deadline.map(|dl| dl * 3 / 4);
        sopts.rendezvous_timeout = rendezvous;
        sopts.max_payload = max_payload;
        sopts.env_fingerprint = env_fp;
        sopts.faults = so
            .run
            .faults
            .as_ref()
            .map(|p| p.injector(net::FaultRole::Shard))
            .filter(|inj| !inj.is_empty());
        // Scrape ports cover the whole tree: each in-process shard gets
        // a metrics endpoint derived from the root's --metrics-addr and
        // a registry labelled role="shard",shard="i".
        if let Some(mep) = &so.metrics_addr {
            sopts.metrics_addr = Some(shard_listen_endpoint(mep, i));
            sopts.metrics = Some(net::MetricsRegistry::shard(i));
        }
        match net::ShardCoordinator::bind(sopts) {
            Ok(sc) => shard_coords.push(sc),
            Err(e) => {
                eprintln!("shard {i} bind: {e}");
                return 1;
            }
        }
    }
    println!("coordinator listening on {root_ep}");
    if let Some(mep) = coordinator.metrics_endpoint() {
        println!("metrics on {mep}");
    }
    for (i, sc) in shard_coords.iter().enumerate() {
        println!("shard {i} listening on {}", sc.local_endpoint());
        if let Some(mep) = sc.metrics_endpoint() {
            println!("shard {i} metrics on {mep}");
        }
    }
    if let Some(path) = &so.endpoint_file {
        let mut eps = vec![root_ep.clone()];
        eps.extend(shard_coords.iter().map(|sc| sc.local_endpoint().clone()));
        let mut comments = Vec::new();
        if let Some(mep) = coordinator.metrics_endpoint() {
            comments.push(format!("# metrics root {mep}"));
        }
        for (i, sc) in shard_coords.iter().enumerate() {
            if let Some(mep) = sc.metrics_endpoint() {
                comments.push(format!("# metrics shard{i} {mep}"));
            }
        }
        if let Err(e) = write_endpoint_file(path, &eps, &comments) {
            eprintln!("endpoint-file {path}: {e}");
            return 1;
        }
    }
    let eval = |p: &[f32]| env.evaluate(p);
    let run_ref = &run;
    let served = std::thread::scope(|s| {
        let handles: Vec<_> = shard_coords
            .into_iter()
            .enumerate()
            .map(|(i, sc)| (i, s.spawn(move || sc.run(run_ref, m, d))))
            .collect();
        let served = coordinator.serve(run_ref, m, init, &eval);
        for (i, h) in handles {
            match h.join() {
                Ok(Ok(st)) => print_shard_stats(i, &st),
                // A drained root closes shard connections without `Fin`
                // (same contract as direct clients) — not a shard fault.
                Ok(Err(net::NetError::Disconnected)) => {
                    println!("[shard {i}] upstream closed before Fin (root drained or failed)")
                }
                Ok(Err(e)) => eprintln!("[shard {i}] {e}"),
                Err(_) => eprintln!("[shard {i}] panicked"),
            }
        }
        served
    });
    match served {
        Ok(hist) => {
            print_net_history("serve", &hist);
            if let Some(path) = &so.history_json {
                if let Err(e) = sparsignd::metrics::write_history_json(path, &hist) {
                    eprintln!("history-json {path}: {e}");
                    return 1;
                }
                println!("wrote {path}");
            }
            0
        }
        // Not a failure: the drain path completed its round (and wrote
        // the snapshot when a policy was set) before exiting so a
        // successor can `--resume`. Exit code 3 lets supervisors tell
        // "drained" from "broken".
        Err(net::NetError::Drained { rounds_done }) => {
            match &so.snapshot {
                Some((path, _)) => println!(
                    "coordinator drained after {rounds_done} rounds (snapshot at {path})"
                ),
                None => println!(
                    "coordinator drained after {rounds_done} rounds (no snapshot policy — \
                     nothing written)"
                ),
            }
            3
        }
        Err(e) => {
            eprintln!("serve: {e}");
            1
        }
    }
}

fn cmd_fleet(args: &ArgMap) -> i32 {
    let fo = match FleetOpts::from_args(args) {
        Ok(f) => f,
        Err(e) => return cli_err(e),
    };
    let setup = match net_setup(&fo.run) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let NetSetup { env, run, init } = setup;
    let mut fleet_opts = net::FleetOptions::default();
    if let Some(agents) = fo.agents {
        fleet_opts.agents = agents;
    }
    fleet_opts.faults = fo
        .run
        .faults
        .as_ref()
        .map(|p| p.injector(net::FaultRole::Client))
        .filter(|inj| !inj.is_empty());

    match &fo.mode {
        // `--shard-line I` serves worker slice `chunk_bounds(m, K, I)`
        // of a K-shard tree as a standalone process, dialing line
        // `1 + I` of the endpoint file on every (re)connect — the soak
        // supervisor's fleet unit, where each sub-fleet must be
        // separately killable.
        FleetMode::ShardLine { file, index, count } => {
            let (i, k) = (*index, *count);
            if fo.reconnect_secs > 0 {
                fleet_opts.reconnect = Some(std::time::Duration::from_secs(fo.reconnect_secs));
            }
            let m = env.fed.workers();
            let (lo, hi) = sparsignd::coordinator::chunk_bounds(m, k, i);
            let src = net::EndpointFileLine(file.into(), 1 + i);
            match net::run_fleet_range(&src, &run, &env, lo, hi, &fleet_opts) {
                Ok(stats) => {
                    print_fleet_stats_tag(&format!("fleet shard {i}"), &stats);
                    0
                }
                Err(e) => {
                    eprintln!("fleet shard {i}: {e}");
                    1
                }
            }
        }

        // `--via-shards` splits the fleet over the shard lines of an
        // endpoint file written by `serve --shards N`: sub-fleet i dials
        // line `1 + i` and hosts worker slice `chunk_bounds(m, N, i)` —
        // the same partition the serving side claimed.
        FleetMode::ViaShards { file } => {
            let body = match std::fs::read_to_string(file) {
                Ok(b) => b,
                Err(e) => {
                    eprintln!("connect-file {file}: {e}");
                    return 2;
                }
            };
            // `# metrics …` comment lines trail the endpoint lines;
            // only real endpoint lines count toward the shard count.
            let nshards = body
                .lines()
                .map(str::trim)
                .filter(|l| !l.is_empty() && !l.starts_with('#'))
                .count()
                .saturating_sub(1);
            if nshards == 0 {
                eprintln!(
                    "connect-file {file} has no shard lines \
                     (serve --shards N writes 1 + N lines)"
                );
                return 2;
            }
            if fo.reconnect_secs > 0 {
                fleet_opts.reconnect = Some(std::time::Duration::from_secs(fo.reconnect_secs));
            }
            let m = env.fed.workers();
            let run_ref = &run;
            let env_ref = &env;
            let fopts = &fleet_opts;
            let results: Vec<_> = std::thread::scope(|s| {
                let handles: Vec<_> = (0..nshards)
                    .map(|i| {
                        let (lo, hi) = sparsignd::coordinator::chunk_bounds(m, nshards, i);
                        let src = net::EndpointFileLine(file.into(), 1 + i);
                        s.spawn(move || net::run_fleet_range(&src, run_ref, env_ref, lo, hi, fopts))
                    })
                    .collect();
                handles.into_iter().map(|h| h.join()).collect()
            });
            let mut code = 0;
            for (i, res) in results.into_iter().enumerate() {
                match res {
                    Ok(Ok(stats)) => print_fleet_stats_tag(&format!("fleet shard {i}"), &stats),
                    Ok(Err(e)) => {
                        eprintln!("fleet shard {i}: {e}");
                        code = 1;
                    }
                    Err(_) => {
                        eprintln!("fleet shard {i}: panicked");
                        code = 1;
                    }
                }
            }
            code
        }

        // Join an external coordinator (by address or through an
        // endpoint file, re-read on every reconnect attempt). External
        // fleets survive coordinator restarts by default; 0 disables
        // (fail fast on the first connection loss).
        FleetMode::ConnectFile { file } => {
            if fo.reconnect_secs > 0 {
                fleet_opts.reconnect = Some(std::time::Duration::from_secs(fo.reconnect_secs));
            }
            let src = net::EndpointFile(file.into());
            match net::run_fleet_src(&src, &run, &env, &fleet_opts) {
                Ok(stats) => {
                    print_fleet_stats(&stats);
                    0
                }
                Err(e) => {
                    eprintln!("fleet: {e}");
                    1
                }
            }
        }
        FleetMode::Connect { addr } => {
            if fo.reconnect_secs > 0 {
                fleet_opts.reconnect = Some(std::time::Duration::from_secs(fo.reconnect_secs));
            }
            match net::run_fleet_src(addr, &run, &env, &fleet_opts) {
                Ok(stats) => {
                    print_fleet_stats(&stats);
                    0
                }
                Err(e) => {
                    eprintln!("fleet: {e}");
                    1
                }
            }
        }

        // Default: the self-contained loopback diff against the
        // in-process engine.
        FleetMode::Loopback { uds, shards, deadline_ms } => {
            // Protocol-level attacks (straggle/equivocate) make
            // acceptance timing-dependent — the in-process engine has
            // no frames to reject — so the bit-identity diff only gates
            // gradient-level (or honest) runs. Attacked-transport runs
            // are judged by their typed rejects.
            let protocol_attacks =
                run.attack.as_ref().map(|p| p.has_protocol_attacks()).unwrap_or(false);
            let in_process =
                (!protocol_attacks).then(|| run.run(&env, init.clone(), &|p| env.evaluate(p)));
            let uds = *uds;
            let mut serve_opts = net::ServeOptions::new(net::client::loopback_endpoint(uds));
            if protocol_attacks {
                // Stragglers hold updates past the round deadline; without
                // one the round would wait for them and the attack would
                // degenerate.
                serve_opts.round_deadline = Some(std::time::Duration::from_millis(*deadline_ms));
            }
            let eval = |p: &[f32]| env.evaluate(p);
            // `--shards N` routes the same loopback run through an
            // in-process aggregation tree (N shard tiers between fleet
            // and root); the bit-identity diff below is the tree's
            // correctness gate.
            let nshards = *shards;
            let (wire_hist, stats) = if nshards > 0 {
                let (hist, stats, shard_stats) = match net::run_loopback_sharded(
                    &run,
                    &env,
                    init,
                    &eval,
                    serve_opts,
                    &fleet_opts,
                    nshards,
                    uds,
                ) {
                    Ok(out) => out,
                    Err(e) => {
                        eprintln!("sharded loopback: {e}");
                        return 1;
                    }
                };
                for (i, st) in shard_stats.iter().enumerate() {
                    print_shard_stats(i, st);
                }
                (hist, stats)
            } else {
                match net::run_loopback(&run, &env, init, &eval, serve_opts, &fleet_opts) {
                    Ok(out) => out,
                    Err(e) => {
                        eprintln!("loopback: {e}");
                        return 1;
                    }
                }
            };
            print_net_history("loopback", &wire_hist);
            print_fleet_stats(&stats);
            match in_process {
                None => {
                    println!(
                        "protocol-level attack plan: loopback diff skipped \
                         (typed rejects above are the acceptance signal)"
                    );
                    0
                }
                Some(in_process) => match diff_histories(&in_process, &wire_hist) {
                    Ok(()) => {
                        println!(
                            "RunHistory identical to the in-process engine (same seed): PASS"
                        );
                        0
                    }
                    Err(e) => {
                        eprintln!("RunHistory DIVERGED from the in-process engine: {e}");
                        1
                    }
                },
            }
        }
    }
}

/// One aggregator shard as its own OS process: bind `--listen`, publish
/// the resolved endpoint, rendezvous upstream (retrying inside the
/// `--reconnect-secs` window — the root may not be up yet), relay
/// rounds until `Fin`. The soak supervisor forks one of these per
/// shard so each is separately killable.
fn cmd_shard(args: &ArgMap) -> i32 {
    let sh = match ShardOpts::from_args(args) {
        Ok(s) => s,
        Err(e) => return cli_err(e),
    };
    let setup = match net_setup(&sh.run) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let NetSetup { env, run, init } = setup;
    let m = env.fed.workers();
    let d = init.len();
    let i = sh.index;
    // Upstream: a fixed address, or line 0 of an endpoint file re-read
    // on every (re)connect so a respawned root's fresh address is
    // picked up. With a file the fixed endpoint is never dialed; any
    // parseable placeholder satisfies the options struct.
    let (upstream, upstream_file) = match &sh.upstream {
        ShardUpstream::File { file } => (
            net::Endpoint::Tcp("127.0.0.1:0".into()),
            Some((std::path::PathBuf::from(file), 0usize)),
        ),
        ShardUpstream::Addr { addr } => (addr.clone(), None),
    };
    let (lo, hi) = sparsignd::coordinator::chunk_bounds(m, sh.shard_count, i);
    let mut sopts = net::ShardOptions::new(upstream, sh.listen.clone(), lo, hi);
    sopts.upstream_file = upstream_file;
    if sh.reconnect_secs > 0 {
        sopts.reconnect = Some(std::time::Duration::from_secs(sh.reconnect_secs));
    }
    sopts.rendezvous_timeout = std::time::Duration::from_secs(sh.rendezvous_secs);
    if sh.deadline_ms > 0 {
        sopts.round_deadline = Some(std::time::Duration::from_millis(sh.deadline_ms));
    }
    sopts.env_fingerprint = env.env_fingerprint();
    sopts.faults = sh
        .run
        .faults
        .as_ref()
        .map(|p| p.injector(net::FaultRole::Shard))
        .filter(|inj| !inj.is_empty());
    // The shard's own scrape port, labelled by tree position (not by
    // worker range — the range can move when K changes).
    if sh.metrics_addr.is_some() {
        sopts.metrics_addr = sh.metrics_addr.clone();
        sopts.metrics = Some(net::MetricsRegistry::shard(i));
    }
    let sc = match net::ShardCoordinator::bind(sopts) {
        Ok(sc) => sc,
        Err(e) => {
            eprintln!("shard {i} bind: {e}");
            return 1;
        }
    };
    println!("shard {i} listening on {}", sc.local_endpoint());
    if let Some(mep) = sc.metrics_endpoint() {
        println!("shard {i} metrics on {mep}");
    }
    if let Some(path) = &sh.publish_file {
        let comments: Vec<String> = sc
            .metrics_endpoint()
            .map(|mep| format!("# metrics shard{i} {mep}"))
            .into_iter()
            .collect();
        if let Err(e) = write_endpoint_file(path, &[sc.local_endpoint().clone()], &comments) {
            eprintln!("publish-file {path}: {e}");
            return 1;
        }
    }
    match sc.run(&run, m, d) {
        Ok(st) => {
            print_shard_stats(i, &st);
            0
        }
        Err(e) => {
            eprintln!("[shard {i}] {e}");
            1
        }
    }
}

/// Churn soak: run the reference and faulted pipelines via
/// [`net::run_soak`] and gate on bit-identical history JSON (and, when
/// the faulted root exposes a scrape port, on the `/metrics` round
/// gauge never going backwards across coordinator generations).
fn cmd_soak(args: &ArgMap) -> i32 {
    let sk = match SoakOpts::from_args(args) {
        Ok(s) => s,
        Err(e) => return cli_err(e),
    };
    let dir = std::path::PathBuf::from(&sk.dir);
    let binary = match std::env::current_exe() {
        Ok(p) => p,
        Err(e) => {
            eprintln!("soak: current_exe: {e}");
            return 1;
        }
    };
    let mut opts = net::SoakOptions::new(dir, binary);
    if let Some(rounds) = sk.rounds {
        opts.rounds = rounds;
    }
    if let Some(clients) = sk.clients {
        opts.clients = clients;
    }
    if let Some(shards) = sk.shards {
        opts.shards = shards;
    }
    if let Some(spec) = &sk.faults {
        opts.faults = spec.clone();
    }
    if let Some(fault_seed) = sk.fault_seed {
        opts.fault_seed = fault_seed;
    }
    opts.uds = sk.uds;
    if let Some(heal) = sk.heal_attempts {
        opts.heal_attempts = heal;
    }
    if let Some(secs) = sk.reconnect_secs {
        opts.reconnect_secs = secs;
    }
    opts.timeout = std::time::Duration::from_secs(sk.timeout_secs);
    // Forward the training flags every child must agree on (the soak
    // children each rebuild the same environment from the same flags,
    // exactly as a distributed serve/fleet pair does).
    opts.pass = sk.pass.clone();
    match net::run_soak(&opts) {
        Ok(report) => {
            println!(
                "[soak] rounds_closed {} | recoverages {} | restarts: coordinator {} \
                 shard {} agent {}",
                report.rounds_closed,
                report.recoverages,
                report.coordinator_restarts,
                report.shard_restarts,
                report.agent_restarts
            );
            println!("[soak] event log: {}", report.event_log.display());
            println!(
                "[soak] metrics: {} scrapes over {} coordinator generations | \
                 round gauge monotonic: {}",
                report.metrics_scrapes,
                report.metrics_generations,
                if report.round_gauge_monotonic { "PASS" } else { "FAIL" }
            );
            if !report.identical {
                eprintln!(
                    "[soak] history DIVERGED under churn: cmp {} {}",
                    report.reference_json.display(),
                    report.faulted_json.display()
                );
                return 1;
            }
            println!("[soak] history bit-identical under churn: PASS");
            if !report.round_gauge_monotonic {
                eprintln!("[soak] metrics round gauge went backwards across generations");
                return 1;
            }
            0
        }
        Err(e) => {
            eprintln!("soak: {e}");
            1
        }
    }
}

fn print_net_history(tag: &str, hist: &RunHistory) {
    let eval = hist.final_eval().map(|(l, a)| format!("loss {l:.4}, acc {a:.3}"));
    println!(
        "[{tag}] {} | {} rounds | uplink {:.1} KiB-est / {:.1} KiB-wire | stragglers {} | {}",
        hist.label,
        hist.ledger.rounds(),
        hist.total_uplink() / 8192.0,
        hist.ledger.total_uplink_wire_bytes() as f64 / 1024.0,
        hist.ledger.total_stragglers(),
        eval.unwrap_or_else(|| "no eval".into())
    );
    // Typed reject counters (BadRound, NotSelected, Duplicate, Late,
    // UnknownWorker, WrongClient) — the CI attack-smoke job greps this.
    let rejects = hist.ledger.rejects_by_kind();
    println!(
        "[{tag}] rejects_by_kind {:?} (total {})",
        rejects,
        hist.ledger.total_rejects()
    );
    // Shard-tier wire traffic (root <-> shards). Nonzero only on runs
    // routed through the aggregation tree — the CI shard-smoke job
    // greps this line to prove the tree actually carried the round.
    let shard_up = hist.ledger.total_shard_uplink_wire_bytes();
    let shard_down = hist.ledger.total_shard_downlink_wire_bytes();
    if shard_up > 0 || shard_down > 0 {
        println!(
            "[{tag}] shard tier {:.1} KiB up / {:.1} KiB down",
            shard_up as f64 / 1024.0,
            shard_down as f64 / 1024.0
        );
    }
}

fn print_fleet_stats(stats: &net::FleetStats) {
    print_fleet_stats_tag("fleet", stats);
}

fn print_fleet_stats_tag(tag: &str, stats: &net::FleetStats) {
    println!(
        "[{tag}] {} updates sent, {} rejected, {} round-opens, {} reconnects, \
         {:.1} KiB up / {:.1} KiB down",
        stats.updates_sent,
        stats.rejected,
        stats.rounds_seen,
        stats.reconnects,
        stats.bytes_up as f64 / 1024.0,
        stats.bytes_down as f64 / 1024.0
    );
}

fn print_shard_stats(i: usize, st: &net::ShardStats) {
    println!(
        "[shard {i}] rounds {}, folded {}, client {:.1} KiB up / {:.1} KiB down, \
         root {:.1} KiB up / {:.1} KiB down",
        st.rounds_relayed,
        st.updates_folded,
        st.client_up_bytes as f64 / 1024.0,
        st.client_down_bytes as f64 / 1024.0,
        st.root_up_bytes as f64 / 1024.0,
        st.root_down_bytes as f64 / 1024.0
    );
    if st.upstream_reconnects > 0 {
        println!("[shard {i}] upstream reconnects {}", st.upstream_reconnects);
    }
}

/// Throughput keys gated by the CI bench-trajectory check (bigger is
/// better; latency keys are reported but not gated — they are noisy on
/// shared runners).
const GATED_KEYS: &[&str] = &[
    "gemm_64x784x256_gflops",
    "gemm_128x256x128_gflops",
    "gemm_256x256x256_gflops",
    "round_throughput_rps",
    "engine10k_rounds_per_sec",
    "transport_rounds_per_sec",
    "wire_encode_frames_per_sec",
    "wire_decode_frames_per_sec",
    "shard_rounds_per_sec",
    "data_store_rows_per_sec",
    "store_shard_rounds_per_sec",
];

fn cmd_benchdiff(args: &ArgMap) -> i32 {
    use sparsignd::metrics::{parse_flat_json, FlatVal};
    if let Err(e) = opts::check_known(args, "benchdiff", &["baseline", "fresh", "tolerance"]) {
        return cli_err(e);
    }
    let (baseline_path, fresh_path) = match (args.get_str("baseline"), args.get_str("fresh")) {
        (Some(b), Some(f)) => (b, f),
        _ => {
            eprintln!("usage: benchdiff --baseline F --fresh F [--tolerance 0.25]");
            return 2;
        }
    };
    let tolerance = args.get::<f64>("tolerance", 0.25);
    let read = |path: &str| -> Result<Vec<(String, FlatVal)>, String> {
        let body = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        parse_flat_json(&body).map_err(|e| format!("{path}: {e}"))
    };
    let baseline = match read(baseline_path) {
        Ok(kv) => kv,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let fresh = match read(fresh_path) {
        Ok(kv) => kv,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let base_num = |key: &str| -> Option<f64> {
        baseline.iter().find(|(k, _)| k == key).and_then(|(_, v)| v.num())
    };

    // Markdown delta table (lands in the CI job summary verbatim).
    println!("## Bench trajectory vs {baseline_path} (tolerance {:.0}%)\n", tolerance * 100.0);
    println!("| key | baseline | fresh | Δ | status |");
    println!("|---|---:|---:|---:|---|");
    let mut regressed: Vec<String> = Vec::new();
    let mut pending = 0usize;
    for (key, val) in &fresh {
        // Non-finite values (a broken bench can emit NaN, which defeats
        // any comparison) fall through to the missing-key sweep below.
        let Some(f) = val.num().filter(|x| x.is_finite()) else { continue };
        let gated = GATED_KEYS.contains(&key.as_str());
        let (b_cell, delta_cell, status) = match base_num(key) {
            Some(b) if b > 0.0 => {
                let delta = (f - b) / b * 100.0;
                let status = if gated && f < b * (1.0 - tolerance) {
                    regressed.push(key.clone());
                    "**REGRESSED**"
                } else if gated {
                    "ok"
                } else {
                    "info"
                };
                (format!("{b:.3}"), format!("{delta:+.1}%"), status)
            }
            _ => {
                if gated {
                    pending += 1;
                }
                ("—".into(), "—".into(), if gated { "no baseline" } else { "info" })
            }
        };
        println!("| {key} | {b_cell} | {f:.3} | {delta_cell} | {status} |");
    }
    // A gated key that vanished from the fresh run — or came back as a
    // string/NaN — is a silent way to disarm the gate; treat it like a
    // full regression once a baseline is armed.
    for &key in GATED_KEYS {
        let usable = fresh
            .iter()
            .any(|(k, v)| k == key && v.num().filter(|x| x.is_finite()).is_some());
        if usable {
            continue;
        }
        match base_num(key) {
            Some(b) if b > 0.0 => {
                regressed.push(format!("{key} (missing from fresh run)"));
                println!("| {key} | {b:.3} | — | — | **MISSING** |");
            }
            _ => {
                pending += 1;
                println!("| {key} | — | — | — | no baseline, missing |");
            }
        }
    }
    println!();
    if pending > 0 {
        println!(
            "{pending} gated key(s) have no committed baseline yet — commit the fresh \
             BENCH json as the rolling baseline to arm the gate."
        );
    }
    if regressed.is_empty() {
        println!("bench trajectory OK");
        0
    } else {
        eprintln!(
            "bench trajectory REGRESSED >{:.0}% on: {}",
            tolerance * 100.0,
            regressed.join(", ")
        );
        1
    }
}

fn cmd_artifacts(args: &ArgMap) -> i32 {
    if let Err(e) = opts::check_known(args, "artifacts", &[]) {
        return cli_err(e);
    }
    match sparsignd::runtime::Runtime::cpu("artifacts") {
        Ok(rt) => {
            println!("platform: {}", rt.platform());
            for name in rt.registry().names() {
                let spec = rt
                    .registry()
                    .spec(&name)
                    .map(|s| format!("{} inputs", s.inputs.len()))
                    .unwrap_or_else(|_| "unmanifested".into());
                println!("  {name:<36} {spec}");
            }
            if rt.registry().is_stale(std::path::Path::new("python/compile")) {
                println!("WARNING: artifacts older than python/compile sources — run `make artifacts`");
            }
            0
        }
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}
