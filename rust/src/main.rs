//! `sparsignd` — the launcher.
//!
//! ```text
//! sparsignd train   [--rounds N] [--alpha A] [--workers M] [--lr X] …
//! sparsignd tables  [--preset fast|paper] [--only table1[,table2…]]
//! sparsignd fig1    [--rounds N] [--lr X] [--csv out.csv]
//! sparsignd fig2    [--rounds N] [--lr X] [--csv out.csv]
//! sparsignd theory  [--trials N]
//! sparsignd artifacts
//! ```
//!
//! Everything the launcher does is also available as a library call; the
//! examples/ binaries show the embedded usage.

use sparsignd::cli::ArgMap;
use sparsignd::config::ExperimentConfig;
use sparsignd::experiments;
use sparsignd::metrics::write_csv;

fn main() {
    let args = ArgMap::from_env();
    let code = match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("tables") => cmd_tables(&args),
        Some("fig1") => cmd_fig(&args, true),
        Some("fig2") => cmd_fig(&args, false),
        Some("theory") => cmd_theory(&args),
        Some("artifacts") => cmd_artifacts(),
        Some(other) => {
            eprintln!("unknown subcommand '{other}'");
            usage();
            2
        }
        None => {
            usage();
            0
        }
    };
    std::process::exit(code);
}

fn usage() {
    println!(
        "sparsignd — magnitude-aware sparsified signSGD (SPARSIGNSGD / EF-SPARSIGNSGD)\n\
         \n\
         subcommands:\n\
         \x20 train      run the fast-preset experiment (override via --rounds/--alpha/…)\n\
         \x20 tables     regenerate the paper's tables (--preset fast|paper, --only …)\n\
         \x20 fig1       Rosenbrock wrong-aggregation figure (sign vs sparsign)\n\
         \x20 fig2       Rosenbrock worker-sampling figure\n\
         \x20 theory     Theorem 1 Monte-Carlo bound check\n\
         \x20 artifacts  list AOT artifacts + staleness"
    );
}

fn apply_cli_overrides(cfg: &mut ExperimentConfig, args: &ArgMap) -> Result<(), String> {
    for (k, v) in args.flag_pairs() {
        if matches!(k, "preset" | "only" | "csv" | "trials" | "config") {
            continue; // launcher-level flags
        }
        cfg.apply_override(k, v)?;
    }
    cfg.validate()
}

fn cmd_train(args: &ArgMap) -> i32 {
    let mut cfg = ExperimentConfig::fast_preset();
    if let Some(path) = args.get_str("config") {
        let body = match std::fs::read_to_string(path) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("config {path}: {e}");
                return 2;
            }
        };
        if let Err(e) = cfg.apply_file(&body) {
            eprintln!("config {path}: {e}");
            return 2;
        }
    }
    if let Err(e) = apply_cli_overrides(&mut cfg, args) {
        eprintln!("{e}");
        return 2;
    }
    let report = experiments::run_classification(&cfg);
    println!("{}", report.table());
    println!(
        "partition skew (mean max class fraction): {:.3}",
        report.mean_max_class_fraction
    );
    0
}

fn cmd_tables(args: &ArgMap) -> i32 {
    let paper = args.get_str("preset").map(|p| p == "paper").unwrap_or(false);
    let only: Option<Vec<String>> = args
        .get_str("only")
        .map(|s| s.split(',').map(|x| x.trim().to_string()).collect());
    let want = |name: &str| only.as_ref().map(|o| o.iter().any(|x| x == name)).unwrap_or(true);

    if want("table1") {
        println!("{}", experiments::run_classification(&experiments::table1_config(paper)).table());
    }
    if want("table2") {
        println!("{}", experiments::run_classification(&experiments::table2_config(paper)).table());
    }
    if want("table3") {
        println!("{}", experiments::run_classification(&experiments::table3_config(paper)).table());
    }
    if want("tables4_7") {
        for cfg in experiments::tables4_7_configs(paper, &[0.1, 0.3, 0.6, 1.0]) {
            println!("{}", experiments::run_classification(&cfg).table());
        }
    }
    0
}

fn cmd_fig(args: &ArgMap, fig1: bool) -> i32 {
    let rounds = args.get::<usize>("rounds", 3_000);
    let lr = args.get::<f64>("lr", 0.01);
    let seed = args.get::<u64>("seed", 7);
    let series = if fig1 {
        experiments::run_fig1(rounds, lr, seed)
    } else {
        experiments::run_fig2(rounds, lr, seed)
    };
    println!(
        "## Fig. {} — Rosenbrock, M=100, 80 sign-flipped workers (eq. 11)",
        if fig1 { 1 } else { 2 }
    );
    for s in &series {
        println!(
            "  {:<28} mean wrong-aggregation {:.3}   F(start) {:>8.2} → F(end) {:>10.2}",
            s.label,
            s.mean_wrong_agg(),
            s.fvalue.first().unwrap_or(&f64::NAN),
            s.final_value()
        );
    }
    if let Some(path) = args.get_str("csv") {
        let mut rows = Vec::new();
        for (t, _) in series[0].fvalue.iter().enumerate() {
            let mut row = vec![t.to_string()];
            for s in &series {
                row.push(format!("{:.6}", s.wrong_agg[t]));
                row.push(format!("{:.6}", s.fvalue[t]));
            }
            rows.push(row);
        }
        let mut headers = vec!["round".to_string()];
        for s in &series {
            headers.push(format!("{} wrong_agg", s.label));
            headers.push(format!("{} F", s.label));
        }
        let h: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        if let Err(e) = write_csv(path, &h, &rows) {
            eprintln!("csv {path}: {e}");
            return 1;
        }
        println!("wrote {path}");
    }
    0
}

fn cmd_theory(args: &ArgMap) -> i32 {
    let trials = args.get::<usize>("trials", 20_000);
    let checks = experiments::theory::sweep(
        &[20, 50, 100, 200, 500],
        &[0.05, 0.1, 0.2, 0.5],
        0.8,
        trials,
        3,
    );
    println!("## Theorem 1 bound check (80% sign-flipped scalars, {trials} trials)");
    println!("{:>5} {:>6} {:>9} {:>9} {:>11} {:>11}", "M", "B", "p_bar", "q_bar", "empirical", "bound");
    let mut ok = true;
    for c in checks {
        let pass = c.empirical <= c.bound + 0.02;
        ok &= pass;
        println!(
            "{:>5} {:>6} {:>9.4} {:>9.4} {:>11.4} {:>11.4} {}",
            c.m,
            c.budget,
            c.p_bar,
            c.q_bar,
            c.empirical,
            c.bound,
            if pass { "" } else { "VIOLATED" }
        );
    }
    if ok {
        0
    } else {
        1
    }
}

fn cmd_artifacts() -> i32 {
    match sparsignd::runtime::Runtime::cpu("artifacts") {
        Ok(rt) => {
            println!("platform: {}", rt.platform());
            for name in rt.registry().names() {
                let spec = rt
                    .registry()
                    .spec(&name)
                    .map(|s| format!("{} inputs", s.inputs.len()))
                    .unwrap_or_else(|_| "unmanifested".into());
                println!("  {name:<36} {spec}");
            }
            if rt.registry().is_stale(std::path::Path::new("python/compile")) {
                println!("WARNING: artifacts older than python/compile sources — run `make artifacts`");
            }
            0
        }
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}
