//! MSB-first bit-level writer/reader over a byte buffer.

/// Append-only bit writer, MSB-first within each byte.
///
/// §Perf: bits accumulate in a 64-bit register and flush to the byte
/// buffer a byte at a time — `push_bits` is O(bytes), not O(bits), which
/// is the Golomb encoder's hot path (see EXPERIMENTS.md §Perf).
#[derive(Default, Clone, Debug)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Pending bits, right-aligned (the low `nacc` bits are valid).
    acc: u64,
    nacc: u8,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Total bits written so far.
    pub fn len_bits(&self) -> usize {
        self.buf.len() * 8 + self.nacc as usize
    }

    #[inline]
    fn flush_full_bytes(&mut self) {
        while self.nacc >= 8 {
            self.nacc -= 8;
            self.buf.push((self.acc >> self.nacc) as u8);
        }
    }

    /// Push a single bit.
    #[inline]
    pub fn push_bit(&mut self, bit: bool) {
        self.acc = (self.acc << 1) | bit as u64;
        self.nacc += 1;
        if self.nacc >= 8 {
            self.flush_full_bytes();
        }
    }

    /// Push the low `n` bits of `value`, MSB-first (n ≤ 64).
    #[inline]
    pub fn push_bits(&mut self, value: u64, n: u8) {
        debug_assert!(n <= 64);
        if n == 0 {
            return;
        }
        // Keep headroom: with nacc ≤ 7 after a flush, chunks of ≤ 56 bits
        // always fit the accumulator; wider pushes split into two halves.
        if n > 56 {
            self.push_bits_small(value >> 32, n - 32);
            self.push_bits_small(value & 0xFFFF_FFFF, 32);
        } else {
            self.push_bits_small(value, n);
        }
    }

    #[inline]
    fn push_bits_small(&mut self, value: u64, n: u8) {
        debug_assert!(n <= 56);
        let mask = if n == 64 { u64::MAX } else { (1u64 << n) - 1 };
        self.acc = (self.acc << n) | (value & mask);
        self.nacc += n;
        self.flush_full_bytes();
    }

    /// Push `n` one-bits followed by a zero (unary coding of n).
    pub fn push_unary(&mut self, n: u64) {
        let mut rem = n;
        while rem >= 32 {
            self.push_bits(0xFFFF_FFFF, 32);
            rem -= 32;
        }
        // `rem` ones + the terminating zero in one call.
        self.push_bits(((1u64 << rem) - 1) << 1, rem as u8 + 1);
    }

    /// Finish and return the byte buffer (final byte zero-padded).
    pub fn into_bytes(mut self) -> Vec<u8> {
        if self.nacc > 0 {
            let pad = 8 - self.nacc;
            self.acc <<= pad;
            self.nacc = 8;
            self.flush_full_bytes();
        }
        self.buf
    }

    pub fn as_bytes(&self) -> &[u8] {
        &self.buf
    }
}

/// Bit reader matching [`BitWriter`]'s layout.
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize, // bit position
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Bits remaining (counting zero padding in the final byte).
    pub fn remaining(&self) -> usize {
        self.buf.len() * 8 - self.pos
    }

    /// Read one bit; `None` at end of buffer.
    #[inline]
    pub fn read_bit(&mut self) -> Option<bool> {
        if self.pos >= self.buf.len() * 8 {
            return None;
        }
        let byte = self.buf[self.pos / 8];
        let bit = (byte >> (7 - (self.pos % 8))) & 1 == 1;
        self.pos += 1;
        Some(bit)
    }

    /// Read `n` bits MSB-first into a u64.
    pub fn read_bits(&mut self, n: u8) -> Option<u64> {
        let mut v = 0u64;
        for _ in 0..n {
            v = (v << 1) | self.read_bit()? as u64;
        }
        Some(v)
    }

    /// Read a unary-coded count (ones terminated by a zero).
    pub fn read_unary(&mut self) -> Option<u64> {
        let mut n = 0;
        loop {
            match self.read_bit()? {
                true => n += 1,
                false => return Some(n),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn roundtrip_bits() {
        let mut w = BitWriter::new();
        w.push_bits(0b1011, 4);
        w.push_bits(0xdead_beef, 32);
        w.push_unary(5);
        assert_eq!(w.len_bits(), 4 + 32 + 6);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(4), Some(0b1011));
        assert_eq!(r.read_bits(32), Some(0xdead_beef));
        assert_eq!(r.read_unary(), Some(5));
    }

    #[test]
    fn roundtrip_random_streams() {
        let mut rng = Pcg64::seed_from(77);
        for _ in 0..20 {
            let items: Vec<(u64, u8)> = (0..100)
                .map(|_| {
                    let n = 1 + rng.index(32) as u8;
                    let v = rng.next_u64() & ((1u64 << n) - 1);
                    (v, n)
                })
                .collect();
            let mut w = BitWriter::new();
            for &(v, n) in &items {
                w.push_bits(v, n);
            }
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            for &(v, n) in &items {
                assert_eq!(r.read_bits(n), Some(v));
            }
        }
    }

    #[test]
    fn read_past_end() {
        let mut w = BitWriter::new();
        w.push_bit(true);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bit(), Some(true));
        // 7 padding bits then None.
        for _ in 0..7 {
            assert_eq!(r.read_bit(), Some(false));
        }
        assert_eq!(r.read_bit(), None);
        assert_eq!(r.read_bits(3), None);
    }

    #[test]
    fn empty_writer() {
        let w = BitWriter::new();
        assert_eq!(w.len_bits(), 0);
        assert!(w.into_bytes().is_empty());
    }
}
