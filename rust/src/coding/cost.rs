//! Closed-form communication-cost models, matching the accounting used for
//! the paper's Tables 1–7.
//!
//! * Ternary messages (sparsign / TernGrad / 1-bit QSGD): Golomb position
//!   coding, paper eq. (12), plus 1 sign bit per non-zero.
//! * Dense 1-bit messages (signSGD, noisy signSGD): `d` bits.
//! * Scaled sign: `d` bits + one f32 scale.
//! * s-level QSGD (FedCom): per Alistarh et al. 2017 Thm 3.4 / their
//!   experimental accounting — one f32 norm + per-coordinate sign+level.

/// Golden ratio φ.
const PHI: f64 = 1.618_033_988_749_895;

/// Paper eq. (12): expected Golomb bits per non-zero index at sparsity
/// (density) `p`:
///
/// `b̄ = b* + 1 / (1 - (1-p)^{2^{b*}})`,
/// `b* = 1 + ⌊log2( log(φ−1) / log(1-p) )⌋`
///
/// (Sattler et al. 2019a; both logs are negative, so the ratio is
/// positive — equivalently `ln φ / |ln(1-p)|` since `ln(φ−1) = −ln φ`).
pub fn golomb_bits_per_index(p: f64) -> f64 {
    let p = p.clamp(1e-12, 1.0 - 1e-12);
    let ratio = PHI.ln() / (1.0 - p).ln().abs();
    let bstar = (1.0 + ratio.log2().floor()).max(0.0);
    bstar + 1.0 / (1.0 - (1.0 - p).powf(2f64.powf(bstar)))
}

/// Uplink cost model for one compressed gradient message.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CostModel {
    /// Dense: every coordinate sent with `bits_per_coord` bits, plus
    /// `overhead_bits` (e.g. norms/scales).
    Dense { bits_per_coord: f64, overhead_bits: f64 },
    /// Sparse ternary: Golomb-coded positions + 1 sign bit per non-zero.
    SparseTernary,
    /// Sparse with full-precision values: positions + 32-bit value each
    /// (Top-k / Random-k / Threshold-v baselines).
    SparseFloat,
    /// QSGD with `s` quantization levels: f32 norm + per-*non-zero*
    /// coordinate (sign + Elias-coded level) + Golomb positions.
    Qsgd { levels: u32 },
}

impl CostModel {
    /// Bits to transmit a message over a `d`-dim gradient with `nnz`
    /// non-zero coordinates.
    pub fn bits(&self, d: usize, nnz: usize) -> f64 {
        match *self {
            CostModel::Dense { bits_per_coord, overhead_bits } => {
                bits_per_coord * d as f64 + overhead_bits
            }
            CostModel::SparseTernary => {
                if nnz == 0 {
                    return 0.0;
                }
                let p = nnz as f64 / d as f64;
                nnz as f64 * (golomb_bits_per_index(p) + 1.0)
            }
            CostModel::SparseFloat => {
                if nnz == 0 {
                    return 0.0;
                }
                let p = nnz as f64 / d as f64;
                nnz as f64 * (golomb_bits_per_index(p) + 32.0)
            }
            CostModel::Qsgd { levels } => {
                if nnz == 0 {
                    return 32.0;
                }
                let p = nnz as f64 / d as f64;
                // Norm (32) + positions + sign + expected Elias level bits.
                // For s levels the level index l ∈ [1, s]; we charge the
                // mean Elias-gamma length under a uniform level assumption,
                // a close upper proxy for Alistarh Thm 3.4's bound.
                let mean_level_bits: f64 = (1..=levels.max(1))
                    .map(|l| crate::coding::elias::gamma_len(l as u64) as f64)
                    .sum::<f64>()
                    / levels.max(1) as f64;
                32.0 + nnz as f64 * (golomb_bits_per_index(p) + 1.0 + mean_level_bits)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq12_reference_values() {
        // Spot values computed from the formula itself (regression guard)
        // plus qualitative shape: sparser ⇒ more bits per index.
        let b01 = golomb_bits_per_index(0.01);
        let b10 = golomb_bits_per_index(0.1);
        let b50 = golomb_bits_per_index(0.5);
        assert!(b01 > b10 && b10 > b50, "{b01} {b10} {b50}");
        // At p=0.5, b* = 1 + floor(log2(ln φ / ln 0.5)) = 1 + floor(-0.527) = 0,
        // b̄ = 0 + 1/(1-0.5) = 2.
        assert!((b50 - 2.0).abs() < 1e-9, "{b50}");
    }

    #[test]
    fn eq12_degenerate_densities() {
        assert!(golomb_bits_per_index(0.0).is_finite());
        assert!(golomb_bits_per_index(1.0).is_finite());
        assert!(golomb_bits_per_index(-3.0).is_finite());
    }

    #[test]
    fn dense_cost() {
        let m = CostModel::Dense { bits_per_coord: 1.0, overhead_bits: 32.0 };
        assert_eq!(m.bits(1000, 1000), 1032.0);
    }

    #[test]
    fn ternary_cost_scales_with_nnz() {
        let m = CostModel::SparseTernary;
        let d = 100_000;
        let c1 = m.bits(d, 1_000);
        let c2 = m.bits(d, 10_000);
        assert!(c2 > c1);
        assert_eq!(m.bits(d, 0), 0.0);
        // Ternary beats dense 1-bit when sparse enough.
        let dense = CostModel::Dense { bits_per_coord: 1.0, overhead_bits: 0.0 };
        assert!(c1 < dense.bits(d, d));
    }

    #[test]
    fn qsgd_cost_includes_norm() {
        let m = CostModel::Qsgd { levels: 1 };
        assert_eq!(m.bits(10, 0), 32.0);
        assert!(m.bits(1000, 100) > 32.0);
        // More levels ⇒ more bits per non-zero.
        let m8 = CostModel::Qsgd { levels: 255 };
        assert!(m8.bits(1000, 100) > m.bits(1000, 100));
    }

    #[test]
    fn sparse_float_dominates_ternary() {
        let t = CostModel::SparseTernary;
        let f = CostModel::SparseFloat;
        assert!(f.bits(10_000, 500) > t.bits(10_000, 500));
    }
}
