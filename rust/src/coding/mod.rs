//! Entropy coding of compressed gradients and the communication-cost
//! models used by the paper's tables.
//!
//! The ternary compressors (sparsign, TernGrad, 1-bit QSGD) transmit a
//! sparse set of ±1 coordinates. Following the paper (§6, eq. (12)) and
//! Sattler et al. (2019a), the positions of the non-zero coordinates are
//! Golomb-coded as index gaps and each non-zero costs one extra sign bit.
//!
//! This module provides both:
//! * the *closed-form cost model* ([`cost`]) the tables use, and
//! * *working encoders/decoders* ([`golomb`], [`elias`], [`bitio`]) whose
//!   measured output validates the model in tests (the real encoder must
//!   stay within a few percent of eq. (12) on Bernoulli-sparse inputs).

pub mod bitio;
pub mod cost;
pub mod elias;
pub mod golomb;

pub use bitio::{BitReader, BitWriter};
pub use cost::{golomb_bits_per_index, CostModel};
