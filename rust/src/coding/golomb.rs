//! Golomb–Rice coding of sparse index sets.
//!
//! A ternary compressed gradient is a set of strictly increasing non-zero
//! positions plus a sign per position. The positions are transmitted as
//! *gaps* (first-difference minus... we code the raw gap `g ≥ 0` between
//! consecutive indices, with the first gap counted from −1 so every gap is
//! ≥ 0... concretely `gap_0 = idx_0`, `gap_j = idx_j - idx_{j-1} - 1`),
//! which are geometrically distributed when non-zeros are Bernoulli(p).
//! Golomb–Rice with parameter `b* = 1 + ⌊log2(log(φ)/log(1-p))⌋`
//! (φ = golden ratio) is the optimal Rice code for that geometric source —
//! the same choice as Sattler et al. (2019a) and the paper's eq. (12).

use super::bitio::{BitReader, BitWriter};

/// Golden ratio φ.
const PHI: f64 = 1.618_033_988_749_895;

/// Optimal Rice parameter `b*` for non-zero density `p ∈ (0, 1)`.
///
/// `b* = 1 + ⌊log2( log(φ) / log(1-p) )⌋`, clamped to ≥ 0. For p → 1 the
/// inner ratio collapses and we fall back to b* = 0 (pure unary, which is
/// optimal when gaps are almost always 0).
pub fn rice_parameter(p: f64) -> u8 {
    if !(0.0..1.0).contains(&p) || p <= 0.0 {
        return 31; // degenerate: effectively fixed-width
    }
    let ratio = PHI.ln().log2() - (1.0 - p).ln().abs().log2();
    let b = 1.0 + ratio.floor();
    if b.is_finite() && b > 0.0 {
        (b as i64).clamp(0, 31) as u8
    } else {
        0
    }
}

/// Encode one non-negative integer with Rice parameter `b`:
/// quotient `n >> b` in unary, remainder in `b` fixed bits.
pub fn encode_value(w: &mut BitWriter, n: u64, b: u8) {
    w.push_unary(n >> b);
    if b > 0 {
        w.push_bits(n & ((1u64 << b) - 1), b);
    }
}

/// Decode one Rice-coded value.
pub fn decode_value(r: &mut BitReader, b: u8) -> Option<u64> {
    let q = r.read_unary()?;
    let rem = if b > 0 { r.read_bits(b)? } else { 0 };
    Some((q << b) | rem)
}

/// Encode a strictly increasing index set over a vector of length `d`,
/// choosing the Rice parameter from the realized density. The parameter
/// (5 bits) and the count (32 bits) are included in the stream so it is
/// self-delimiting.
///
/// Returns the encoded bytes; total cost in bits is `8 * bytes.len()`
/// rounded down to [`BitWriter::len_bits`] before padding.
pub fn encode_indices(indices: &[usize], d: usize) -> (Vec<u8>, usize) {
    assert!(
        indices.windows(2).all(|w| w[0] < w[1]),
        "indices must be strictly increasing"
    );
    if let Some(&last) = indices.last() {
        assert!(last < d, "index {last} out of range for d={d}");
    }
    let p = if d == 0 { 0.0 } else { indices.len() as f64 / d as f64 };
    let b = rice_parameter(p);
    let mut w = BitWriter::new();
    w.push_bits(b as u64, 5);
    w.push_bits(indices.len() as u64, 32);
    let mut prev: i64 = -1;
    for &idx in indices {
        let gap = (idx as i64 - prev - 1) as u64;
        encode_value(&mut w, gap, b);
        prev = idx as i64;
    }
    let bits = w.len_bits();
    (w.into_bytes(), bits)
}

/// Decode an index set produced by [`encode_indices`].
pub fn decode_indices(bytes: &[u8]) -> Option<Vec<usize>> {
    let mut r = BitReader::new(bytes);
    let b = r.read_bits(5)? as u8;
    let count = r.read_bits(32)? as usize;
    let mut out = Vec::with_capacity(count);
    let mut prev: i64 = -1;
    for _ in 0..count {
        let gap = decode_value(&mut r, b)? as i64;
        let idx = prev + 1 + gap;
        out.push(idx as usize);
        prev = idx;
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::cost::golomb_bits_per_index;
    use crate::util::rng::Pcg64;

    #[test]
    fn value_roundtrip_all_params() {
        for b in 0..12u8 {
            let mut w = BitWriter::new();
            let vals = [0u64, 1, 2, 7, 63, 64, 1000];
            for &v in &vals {
                encode_value(&mut w, v, b);
            }
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            for &v in &vals {
                assert_eq!(decode_value(&mut r, b), Some(v), "b={b} v={v}");
            }
        }
    }

    #[test]
    fn indices_roundtrip() {
        let mut rng = Pcg64::seed_from(9);
        for &p in &[0.001, 0.01, 0.1, 0.5, 0.9] {
            let d = 10_000;
            let idx: Vec<usize> = (0..d).filter(|_| rng.bernoulli(p)).collect();
            let (bytes, _bits) = encode_indices(&idx, d);
            assert_eq!(decode_indices(&bytes).unwrap(), idx);
        }
    }

    #[test]
    fn empty_and_full_sets() {
        let (bytes, bits) = encode_indices(&[], 100);
        assert_eq!(decode_indices(&bytes).unwrap(), Vec::<usize>::new());
        assert_eq!(bits, 37); // header only: 5 + 32
        let all: Vec<usize> = (0..64).collect();
        let (bytes, _) = encode_indices(&all, 64);
        assert_eq!(decode_indices(&bytes).unwrap(), all);
    }

    #[test]
    fn measured_cost_tracks_eq12_model() {
        // The realized Golomb stream should stay within ~15% of the paper's
        // eq. (12) per-index estimate for Bernoulli-sparse supports.
        let mut rng = Pcg64::seed_from(10);
        let d = 200_000;
        for &p in &[0.005, 0.02, 0.1, 0.3] {
            let idx: Vec<usize> = (0..d).filter(|_| rng.bernoulli(p)).collect();
            let (_, bits) = encode_indices(&idx, d);
            let payload = bits as f64 - 37.0;
            let per_index = payload / idx.len() as f64;
            let model = golomb_bits_per_index(idx.len() as f64 / d as f64);
            let rel = (per_index - model).abs() / model;
            assert!(
                rel < 0.15,
                "p={p}: measured {per_index:.3} vs model {model:.3} (rel {rel:.3})"
            );
        }
    }

    #[test]
    fn rice_parameter_sanity() {
        // Sparser ⇒ larger parameter.
        assert!(rice_parameter(0.001) > rice_parameter(0.01));
        assert!(rice_parameter(0.01) > rice_parameter(0.2));
        // Degenerate densities do not panic.
        let _ = rice_parameter(0.0);
        let _ = rice_parameter(1.0);
        let _ = rice_parameter(-0.5);
    }
}
