//! Elias gamma / delta universal codes.
//!
//! Used for QSGD's level encoding: Alistarh et al. (2017, Thm 3.4) bound the
//! QSGD message size via Elias-coded integer magnitudes; our FedCom
//! baseline (8-bit QSGD) accounts bits with the same scheme.

use super::bitio::{BitReader, BitWriter};

/// Elias-gamma encode `n ≥ 1`: ⌊log2 n⌋ zeros, then `n`'s binary digits.
pub fn gamma_encode(w: &mut BitWriter, n: u64) {
    assert!(n >= 1, "Elias gamma is defined for n >= 1");
    let bits = 64 - n.leading_zeros() as u8; // position of MSB, 1-based
    for _ in 0..bits - 1 {
        w.push_bit(false);
    }
    w.push_bits(n, bits);
}

/// Decode an Elias-gamma value.
pub fn gamma_decode(r: &mut BitReader) -> Option<u64> {
    let mut zeros = 0u8;
    loop {
        match r.read_bit()? {
            false => zeros += 1,
            true => break,
        }
        if zeros > 63 {
            return None;
        }
    }
    let rest = if zeros > 0 { r.read_bits(zeros)? } else { 0 };
    Some((1u64 << zeros) | rest)
}

/// Elias-delta encode `n ≥ 1`: gamma-code the bit length, then the digits
/// of `n` below the MSB.
pub fn delta_encode(w: &mut BitWriter, n: u64) {
    assert!(n >= 1);
    let bits = 64 - n.leading_zeros() as u8;
    gamma_encode(w, bits as u64);
    if bits > 1 {
        w.push_bits(n & ((1u64 << (bits - 1)) - 1), bits - 1);
    }
}

/// Decode an Elias-delta value.
pub fn delta_decode(r: &mut BitReader) -> Option<u64> {
    let bits = gamma_decode(r)? as u8;
    if bits == 0 || bits > 64 {
        return None;
    }
    let rest = if bits > 1 { r.read_bits(bits - 1)? } else { 0 };
    Some(if bits == 64 {
        (1u64 << 63) | rest
    } else {
        (1u64 << (bits - 1)) | rest
    })
}

/// Bit length of the Elias-gamma code for `n`.
pub fn gamma_len(n: u64) -> usize {
    let bits = 64 - n.leading_zeros() as usize;
    2 * bits - 1
}

/// Bit length of the Elias-delta code for `n`.
pub fn delta_len(n: u64) -> usize {
    let bits = 64 - n.leading_zeros() as usize;
    gamma_len(bits as u64) + bits - 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn gamma_roundtrip() {
        let mut w = BitWriter::new();
        let vals = [1u64, 2, 3, 4, 7, 8, 100, 1_000_000];
        for &v in &vals {
            gamma_encode(&mut w, v);
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &v in &vals {
            assert_eq!(gamma_decode(&mut r), Some(v));
        }
    }

    #[test]
    fn delta_roundtrip_random() {
        let mut rng = Pcg64::seed_from(5);
        let vals: Vec<u64> = (0..500).map(|_| 1 + rng.below(1 << 40)).collect();
        let mut w = BitWriter::new();
        for &v in &vals {
            delta_encode(&mut w, v);
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &v in &vals {
            assert_eq!(delta_decode(&mut r), Some(v));
        }
    }

    #[test]
    fn lengths_match_streams() {
        for &v in &[1u64, 2, 5, 31, 32, 1_000_003] {
            let mut w = BitWriter::new();
            gamma_encode(&mut w, v);
            assert_eq!(w.len_bits(), gamma_len(v), "gamma {v}");
            let mut w = BitWriter::new();
            delta_encode(&mut w, v);
            assert_eq!(w.len_bits(), delta_len(v), "delta {v}");
        }
    }

    #[test]
    fn known_codewords() {
        // gamma(1) = "1", gamma(2) = "010", gamma(4) = "00100".
        assert_eq!(gamma_len(1), 1);
        assert_eq!(gamma_len(2), 3);
        assert_eq!(gamma_len(4), 5);
    }
}
