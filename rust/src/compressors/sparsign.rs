//! The paper's contribution: magnitude-driven sparsified sign compression
//! (Definition 1).
//!
//! ```text
//! sparsign(g_i, B_i) = sign(g_i)  with probability |g_i| · B_i
//!                    = 0          otherwise
//! ```
//!
//! The keep-probability is proportional to the coordinate's *magnitude*, so
//! the expected message `E[Q(g)_i] = B_i · g_i` preserves the heterogeneity
//! information that plain sign discards — this is exactly what makes
//! `q̄ > p̄` in Theorem 1 hold for arbitrary gradient realizations
//! (Corollary 1), restoring convergence under heterogeneous data.
//!
//! Per Remark 7, probabilities `|g_i|·B` that exceed 1 are clamped —
//! equivalent to gradient clipping at `1/B`.

use super::{ternary_bits, CompressedGrad, Compressor, PackedTernary};
use crate::coding::cost::CostModel;
use crate::util::rng::{bernoulli_threshold, Pcg64, U32Stream};

/// sparsign with a scalar budget `B` shared across coordinates, the
/// configuration used in Theorems 2–3 and all of the paper's experiments
/// (`B ∈ {0.01, 0.1, 1}`, `B_l = 10`, `B_g = 1`).
///
/// Expected density is `min(1, B·|g_i|)` per coordinate, i.e.
/// `E[nnz] = Σ_i min(1, B·|g_i|)`; communication scales with `B`.
#[derive(Clone, Copy, Debug)]
pub struct SparsignCompressor {
    /// The compression budget `B ≥ 0`; larger B keeps more coordinates.
    pub budget: f32,
}

impl SparsignCompressor {
    /// Expected number of non-zero entries for gradient `g`
    /// (`Σ_i min(1, B·|g_i|)` — Definition 1).
    pub fn expected_nnz(&self, g: &[f32]) -> f64 {
        g.iter()
            .map(|x| (self.budget as f64 * x.abs() as f64).min(1.0))
            .sum()
    }

    /// Streaming emission into a reusable packed message — the engine's
    /// zero-allocation path; `compress` wraps it, so both consume the
    /// same RNG stream. Returns the Golomb-accounted bit cost.
    fn emit_into(&self, g: &[f32], rng: &mut Pcg64, out: &mut PackedTernary) -> f64 {
        assert!(
            self.budget >= 0.0 && self.budget.is_finite(),
            "sparsign budget must be finite and non-negative, got {}",
            self.budget
        );
        let mut pk = out.start(g.len());
        let b = self.budget;
        // §Perf fast path: one raw u64 feeds two branch-free f32-domain
        // Bernoulli comparisons (`u < p·2³²`); p ≥ 1 always fires because
        // every u32 < 2³², so the Remark 7 clipping behaviour falls out of
        // the comparison. Codes go straight into the packed bitplanes —
        // no `Vec<i8>` is ever materialized. See EXPERIMENTS.md §Perf.
        let pairs = g.len() / 2;
        for idx in 0..pairs {
            let r = rng.next_u64();
            let i = 2 * idx;
            let g0 = g[i];
            let g1 = g[i + 1];
            let keep0 = ((r as u32) as f32) < bernoulli_threshold(b * g0.abs());
            let keep1 = (((r >> 32) as u32) as f32) < bernoulli_threshold(b * g1.abs());
            pk.push(if keep0 {
                if g0 > 0.0 {
                    1
                } else {
                    -1
                }
            } else {
                0
            });
            pk.push(if keep1 {
                if g1 > 0.0 {
                    1
                } else {
                    -1
                }
            } else {
                0
            });
        }
        if g.len() % 2 == 1 {
            let gi = g[g.len() - 1];
            let mut u = U32Stream::new(rng);
            pk.push(if u.bernoulli(bernoulli_threshold(b * gi.abs())) {
                if gi > 0.0 {
                    1
                } else {
                    -1
                }
            } else {
                0
            });
        }
        let nnz = pk.nnz();
        pk.finish(1.0);
        ternary_bits(g.len(), nnz, false)
    }
}

impl Compressor for SparsignCompressor {
    fn compress(&mut self, g: &[f32], rng: &mut Pcg64) -> CompressedGrad {
        let mut pack = PackedTernary::zeros(0, 1.0);
        let bits = self.emit_into(g, rng, &mut pack);
        CompressedGrad::ternary(pack, bits)
    }

    fn compress_ternary_into(
        &mut self,
        g: &[f32],
        rng: &mut Pcg64,
        out: &mut PackedTernary,
    ) -> Option<f64> {
        Some(self.emit_into(g, rng, out))
    }

    fn name(&self) -> String {
        format!("sparsign(B={})", self.budget)
    }

    fn cost_model(&self) -> CostModel {
        CostModel::SparseTernary
    }
}

/// Auto-density sparsign: Remark 7 notes "multiple ways to set the
/// compression budgets"; this variant picks `B` per message so the
/// *expected density* is held at `target_density`, i.e.
/// `B = target·d / ‖g‖₁` — a magnitude-sharing-free protocol that keeps
/// the uplink budget constant as gradients shrink during training.
#[derive(Clone, Copy, Debug)]
pub struct SparsignAutoCompressor {
    /// Target expected fraction of non-zero coordinates, in (0, 1].
    pub target_density: f32,
}

impl SparsignAutoCompressor {
    /// The per-message budget `B = target·d / ‖g‖₁`, or `None` for an
    /// all-zero gradient. The ℓ1 norm accumulates in `f64`
    /// (`util::l1_norm_f64`): a plain `f32` running sum loses low-order
    /// mass once the partial sum dwarfs the addends (for `d ≳ 10⁶`
    /// small-magnitude gradients the drift reaches percents), which would
    /// silently skew the derived budget — and with it the expected uplink
    /// density — as models grow.
    pub fn derived_budget(&self, g: &[f32]) -> Option<f32> {
        assert!(
            self.target_density > 0.0 && self.target_density <= 1.0,
            "target density must be in (0,1], got {}",
            self.target_density
        );
        let l1 = crate::util::l1_norm_f64(g);
        if l1 == 0.0 {
            None
        } else {
            Some((self.target_density as f64 * g.len() as f64 / l1) as f32)
        }
    }
}

impl Compressor for SparsignAutoCompressor {
    fn compress(&mut self, g: &[f32], rng: &mut Pcg64) -> CompressedGrad {
        match self.derived_budget(g) {
            None => CompressedGrad::ternary(PackedTernary::zeros(g.len(), 1.0), 0.0),
            Some(budget) => SparsignCompressor { budget }.compress(g, rng),
        }
    }

    fn compress_ternary_into(
        &mut self,
        g: &[f32],
        rng: &mut Pcg64,
        out: &mut PackedTernary,
    ) -> Option<f64> {
        match self.derived_budget(g) {
            None => {
                out.reset(g.len(), 1.0);
                Some(0.0)
            }
            Some(budget) => Some(SparsignCompressor { budget }.emit_into(g, rng, out)),
        }
    }

    fn name(&self) -> String {
        format!("sparsign-auto(p={})", self.target_density)
    }

    fn cost_model(&self) -> CostModel {
        CostModel::SparseTernary
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{self, gen, PropConfig};

    #[test]
    fn auto_density_tracks_target_across_scales() {
        // Density stays ≈ target even when the gradient scale varies by
        // orders of magnitude (the property fixed-B lacks).
        let mut rng_data = Pcg64::seed_from(40);
        let mut base = vec![0.0f32; 8_192];
        rng_data.fill_normal(&mut base, 0.0, 1.0);
        for &scale in &[1e-3f32, 1.0, 1e3] {
            let g: Vec<f32> = base.iter().map(|x| x * scale).collect();
            let mut c = SparsignAutoCompressor { target_density: 0.05 };
            let mut rng = Pcg64::seed_from(41);
            let reps = 16;
            let nnz: usize = (0..reps).map(|_| c.compress(&g, &mut rng).nnz()).sum();
            let density = nnz as f64 / (reps * g.len()) as f64;
            assert!(
                (density - 0.05).abs() < 0.015,
                "scale {scale}: density {density:.4}"
            );
        }
    }

    #[test]
    fn auto_budget_accumulates_l1_in_f64() {
        // Adversarial mass distribution: one 16.0 head followed by 2²¹
        // coordinates of 5e-7. In a sequential f32 sum every tiny addend
        // rounds away (5e-7 < ulp(16)/2), stalling ‖g‖₁ at 16 and
        // inflating the derived budget by ~6.5%; the f64 accumulator
        // captures the full 16 + 2²¹·5e-7 ≈ 17.049.
        let tiny = 5e-7f32;
        let d_tail = 1usize << 21;
        let mut g = vec![tiny; d_tail + 1];
        g[0] = 16.0;
        let l1_exact = 16.0f64 + d_tail as f64 * tiny as f64;
        let c = SparsignAutoCompressor { target_density: 0.05 };
        let budget = c.derived_budget(&g).expect("nonzero gradient") as f64;
        let want = 0.05 * g.len() as f64 / l1_exact;
        let rel = (budget - want).abs() / want;
        assert!(rel < 1e-4, "budget {budget} vs exact {want} (rel {rel:.2e})");
        // The f32-accumulated value would be ≥6% off — make sure we are
        // nowhere near it.
        let stalled = 0.05 * g.len() as f64 / 16.0;
        assert!((budget - stalled).abs() / stalled > 0.05, "budget tracks the stalled f32 sum");
    }

    #[test]
    fn auto_density_zero_gradient() {
        let mut c = SparsignAutoCompressor { target_density: 0.1 };
        let mut rng = Pcg64::seed_from(42);
        let msg = c.compress(&[0.0; 16], &mut rng);
        assert_eq!(msg.nnz(), 0);
        assert_eq!(msg.bits(), 0.0);
    }

    #[test]
    #[should_panic(expected = "target density")]
    fn auto_density_validates_target() {
        let mut c = SparsignAutoCompressor { target_density: 0.0 };
        let mut rng = Pcg64::seed_from(43);
        c.compress(&[1.0], &mut rng);
    }

    fn compress(g: &[f32], b: f32, seed: u64) -> Vec<i8> {
        let mut c = SparsignCompressor { budget: b };
        let mut rng = Pcg64::seed_from(seed);
        match c.compress(g, &mut rng) {
            CompressedGrad::Ternary { pack, .. } => pack.to_codes(),
            _ => unreachable!(),
        }
    }

    #[test]
    fn output_is_ternary_with_matching_signs() {
        testing::check_vec(
            PropConfig { cases: 64, seed: 0xabc },
            (1, 256),
            gen::f32_gradient_like(),
            |g| {
                let q = compress(g, 0.7, 42);
                for (&qi, &gi) in q.iter().zip(g) {
                    if ![-1i8, 0, 1].contains(&qi) {
                        return Err(format!("non-ternary code {qi}"));
                    }
                    if qi != 0 && (qi as f32) * gi <= 0.0 {
                        return Err(format!("sign mismatch q={qi} g={gi}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn zero_gradient_transmits_nothing() {
        let q = compress(&[0.0; 100], 10.0, 1);
        assert!(q.iter().all(|&x| x == 0));
        let mut c = SparsignCompressor { budget: 10.0 };
        let mut rng = Pcg64::seed_from(1);
        let msg = c.compress(&[0.0; 100], &mut rng);
        assert_eq!(msg.bits(), 0.0);
    }

    #[test]
    fn budget_zero_transmits_nothing() {
        let g = vec![1.0, -5.0, 0.25];
        let q = compress(&g, 0.0, 2);
        assert!(q.iter().all(|&x| x == 0));
    }

    #[test]
    fn clipping_regime_keeps_everything() {
        // |g|·B ≥ 1 everywhere ⇒ deterministic sign output (Remark 7).
        let g = vec![2.0, -3.0, 1.0, -1.0];
        let q = compress(&g, 1.0, 3);
        assert_eq!(q, vec![1, -1, 1, -1]);
    }

    #[test]
    fn keep_rate_tracks_magnitude() {
        // E[Q(g)_i] = B·g_i before clipping: empirical keep-rate per
        // coordinate ≈ B·|g_i|.
        let b = 0.5f32;
        let g = vec![0.1f32, 0.4, 0.9, 1.6]; // last one clips at p=0.8
        let trials = 40_000;
        let mut keeps = [0usize; 4];
        let mut c = SparsignCompressor { budget: b };
        let mut rng = Pcg64::seed_from(4);
        for _ in 0..trials {
            if let CompressedGrad::Ternary { pack, .. } = c.compress(&g, &mut rng) {
                pack.for_each_nonzero(|i, _| keeps[i] += 1);
            }
        }
        for (i, &k) in keeps.iter().enumerate() {
            let want = (b * g[i]).min(1.0) as f64;
            let got = k as f64 / trials as f64;
            assert!(
                (got - want).abs() < 0.01,
                "coord {i}: keep rate {got} vs expected {want}"
            );
        }
    }

    #[test]
    fn unbiased_below_clipping() {
        // E[Q(g)] = B·g when B·|g| ≤ 1.
        let b = 0.25f32;
        let g = vec![0.8f32, -1.2, 0.05, -2.0];
        let trials = 60_000;
        let mut sums = [0.0f64; 4];
        let mut c = SparsignCompressor { budget: b };
        let mut rng = Pcg64::seed_from(5);
        for _ in 0..trials {
            if let CompressedGrad::Ternary { pack, .. } = c.compress(&g, &mut rng) {
                pack.for_each_nonzero(|i, q| sums[i] += q as f64);
            }
        }
        for (i, &s) in sums.iter().enumerate() {
            let mean = s / trials as f64;
            let want = (b * g[i]) as f64;
            assert!(
                (mean - want).abs() < 0.012,
                "coord {i}: E[Q] {mean} vs B·g {want}"
            );
        }
    }

    #[test]
    fn expected_nnz_formula_matches_empirical() {
        let b = 0.3f32;
        let g: Vec<f32> = (0..64).map(|i| (i as f32 - 30.0) / 10.0).collect();
        let c = SparsignCompressor { budget: b };
        let want = c.expected_nnz(&g);
        let trials = 4_000;
        let mut total = 0usize;
        let mut cc = c;
        let mut rng = Pcg64::seed_from(6);
        for _ in 0..trials {
            total += cc.compress(&g, &mut rng).nnz();
        }
        let got = total as f64 / trials as f64;
        assert!((got - want).abs() < 0.5, "E[nnz] {got} vs {want}");
    }

    #[test]
    fn bits_monotone_in_budget() {
        let g: Vec<f32> = (0..4096).map(|i| ((i * 37 % 100) as f32 - 50.0) / 500.0).collect();
        let mut prev = -1.0f64;
        for &b in &[0.01f32, 0.1, 1.0, 10.0] {
            let mut c = SparsignCompressor { budget: b };
            let mut rng = Pcg64::seed_from(7);
            // Average over a few draws to suppress sampling noise.
            let bits: f64 =
                (0..16).map(|_| c.compress(&g, &mut rng).bits()).sum::<f64>() / 16.0;
            assert!(bits >= prev, "bits not monotone: B={b} bits={bits} prev={prev}");
            prev = bits;
        }
    }

    #[test]
    #[should_panic(expected = "budget must be finite")]
    fn negative_budget_rejected() {
        let mut c = SparsignCompressor { budget: -1.0 };
        let mut rng = Pcg64::seed_from(8);
        c.compress(&[1.0], &mut rng);
    }

    #[test]
    fn deterministic_given_seed() {
        let g: Vec<f32> = (0..512).map(|i| ((i % 17) as f32 - 8.0) / 8.0).collect();
        let a = compress(&g, 0.4, 99);
        let b = compress(&g, 0.4, 99);
        assert_eq!(a, b);
    }
}
