//! Stochastic-sign baselines from the related work:
//!
//! * [`StoSignCompressor`] — the *stochastic sign* operator used by
//!   sto-SIGNSGD (Jin et al. 2020) and as the building block of SSDM:
//!   `Q(g_i) = +1 w.p. (b + g_i)/(2b), −1 otherwise` (clamped), which is
//!   unbiased up to the known scale `1/b`. One bit per coordinate.
//! * [`SsdmCompressor`] — SSDM (Safaryan & Richtárik 2021): worker-side
//!   momentum `v ← (1−β)·v + β·g` followed by the stochastic sign of the
//!   momentum, normalized by its ℓ∞ norm. **Stateful on the worker** —
//!   exactly the property the paper argues breaks under worker sampling,
//!   so the engine guards it the same way as worker-EF.

use super::{CompressedGrad, Compressor, PackedTernary};
use crate::coding::cost::CostModel;
use crate::util::linf_norm;
use crate::util::rng::Pcg64;

/// Stochastic sign with magnitude parameter `b` (must dominate `|g_i|`;
/// values beyond `b` are clamped — the same clipping semantics as
/// sparsign's Remark 7).
#[derive(Clone, Copy, Debug)]
pub struct StoSignCompressor {
    /// Scale parameter `b > 0`.
    pub b: f32,
}

impl StoSignCompressor {
    /// Streaming emission into a reusable packed message (same RNG stream
    /// as `compress`); returns the message bit cost.
    fn emit_into(&self, g: &[f32], rng: &mut Pcg64, out: &mut PackedTernary) -> f64 {
        assert!(self.b > 0.0, "sto-sign scale must be positive");
        let inv = 1.0 / (2.0 * self.b);
        let mut pk = out.start(g.len());
        for &gi in g.iter() {
            let p_plus = ((self.b + gi) * inv).clamp(0.0, 1.0);
            pk.push(if rng.f32() < p_plus { 1 } else { -1 });
        }
        pk.finish(1.0);
        g.len() as f64
    }
}

impl Compressor for StoSignCompressor {
    fn compress(&mut self, g: &[f32], rng: &mut Pcg64) -> CompressedGrad {
        let mut pack = PackedTernary::zeros(0, 1.0);
        let bits = self.emit_into(g, rng, &mut pack);
        CompressedGrad::ternary(pack, bits)
    }

    fn compress_ternary_into(
        &mut self,
        g: &[f32],
        rng: &mut Pcg64,
        out: &mut PackedTernary,
    ) -> Option<f64> {
        Some(self.emit_into(g, rng, out))
    }

    fn name(&self) -> String {
        format!("sto-sign(b={})", self.b)
    }

    fn cost_model(&self) -> CostModel {
        CostModel::Dense { bits_per_coord: 1.0, overhead_bits: 0.0 }
    }
}

/// SSDM: momentum + normalized stochastic sign.
pub struct SsdmCompressor {
    /// Momentum coefficient β ∈ (0, 1].
    pub beta: f32,
    momentum: Vec<f32>,
}

impl SsdmCompressor {
    pub fn new(beta: f32, dim: usize) -> Self {
        assert!(beta > 0.0 && beta <= 1.0, "β must be in (0,1], got {beta}");
        Self { beta, momentum: vec![0.0; dim] }
    }

    /// Current momentum (diagnostics).
    pub fn momentum(&self) -> &[f32] {
        &self.momentum
    }
}

impl SsdmCompressor {
    /// Momentum update + streaming emission into a reusable packed
    /// message (same RNG stream as `compress`); returns the bit cost.
    fn emit_into(&mut self, g: &[f32], rng: &mut Pcg64, out: &mut PackedTernary) -> f64 {
        assert_eq!(
            g.len(),
            self.momentum.len(),
            "SSDM momentum dim {} != gradient dim {}",
            self.momentum.len(),
            g.len()
        );
        let beta = self.beta;
        for (v, &gi) in self.momentum.iter_mut().zip(g.iter()) {
            *v = (1.0 - beta) * *v + beta * gi;
        }
        let norm = linf_norm(&self.momentum);
        if norm == 0.0 {
            out.reset(g.len(), 1.0);
            return g.len() as f64;
        }
        let inv = 1.0 / (2.0 * norm);
        let mut pk = out.start(g.len());
        for &vi in self.momentum.iter() {
            let p_plus = ((norm + vi) * inv).clamp(0.0, 1.0);
            pk.push(if rng.f32() < p_plus { 1 } else { -1 });
        }
        pk.finish(1.0);
        g.len() as f64
    }
}

impl Compressor for SsdmCompressor {
    fn compress(&mut self, g: &[f32], rng: &mut Pcg64) -> CompressedGrad {
        let mut pack = PackedTernary::zeros(0, 1.0);
        let bits = self.emit_into(g, rng, &mut pack);
        CompressedGrad::ternary(pack, bits)
    }

    fn compress_ternary_into(
        &mut self,
        g: &[f32],
        rng: &mut Pcg64,
        out: &mut PackedTernary,
    ) -> Option<f64> {
        Some(self.emit_into(g, rng, out))
    }

    fn name(&self) -> String {
        format!("ssdm(beta={})", self.beta)
    }

    fn requires_worker_state(&self) -> bool {
        true // momentum lives on the worker across rounds
    }

    fn cost_model(&self) -> CostModel {
        CostModel::Dense { bits_per_coord: 1.0, overhead_bits: 0.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stosign_is_unbiased_up_to_scale() {
        // E[Q(g_i)] = g_i / b.
        let b = 2.0f32;
        let g = vec![0.5f32, -1.0, 0.0, 1.5];
        let mut c = StoSignCompressor { b };
        let mut rng = Pcg64::seed_from(1);
        let trials = 60_000;
        let mut sums = vec![0.0f64; 4];
        for _ in 0..trials {
            for (s, v) in sums.iter_mut().zip(c.compress(&g, &mut rng).to_dense()) {
                *s += v as f64;
            }
        }
        for (i, &s) in sums.iter().enumerate() {
            let mean = s / trials as f64;
            let want = (g[i] / b) as f64;
            assert!((mean - want).abs() < 0.015, "coord {i}: {mean} vs {want}");
        }
    }

    #[test]
    fn stosign_clamps_out_of_range() {
        let mut c = StoSignCompressor { b: 1.0 };
        let mut rng = Pcg64::seed_from(2);
        let g = vec![10.0f32, -10.0];
        for _ in 0..100 {
            let d = c.compress(&g, &mut rng).to_dense();
            assert_eq!(d, vec![1.0, -1.0]); // saturated probabilities
        }
    }

    #[test]
    fn ssdm_momentum_accumulates_and_is_stateful() {
        let mut c = SsdmCompressor::new(0.5, 3);
        let mut rng = Pcg64::seed_from(3);
        let g = vec![1.0f32, -1.0, 0.5];
        c.compress(&g, &mut rng);
        // v = 0.5·g after one step.
        for (v, &gi) in c.momentum().iter().zip(&g) {
            assert!((v - 0.5 * gi).abs() < 1e-6);
        }
        c.compress(&g, &mut rng);
        // v = 0.75·g after two identical steps.
        for (v, &gi) in c.momentum().iter().zip(&g) {
            assert!((v - 0.75 * gi).abs() < 1e-6);
        }
        assert!(c.requires_worker_state());
    }

    #[test]
    fn ssdm_sign_statistics_follow_momentum() {
        // With a stationary gradient the +1 frequency on a coordinate
        // approaches (‖v‖∞ + v_i)/(2‖v‖∞).
        let mut c = SsdmCompressor::new(1.0, 2); // β=1 ⇒ v = g
        let mut rng = Pcg64::seed_from(4);
        let g = vec![1.0f32, -0.5];
        let trials = 40_000;
        let mut plus = [0usize; 2];
        for _ in 0..trials {
            let d = c.compress(&g, &mut rng).to_dense();
            for (p, &v) in plus.iter_mut().zip(&d) {
                if v > 0.0 {
                    *p += 1;
                }
            }
        }
        let f0 = plus[0] as f64 / trials as f64; // (1+1)/2 = 1.0
        let f1 = plus[1] as f64 / trials as f64; // (1-0.5)/2 = 0.25
        assert!(f0 > 0.99, "{f0}");
        assert!((f1 - 0.25).abs() < 0.01, "{f1}");
    }

    #[test]
    fn ssdm_zero_gradient_stream() {
        let mut c = SsdmCompressor::new(0.9, 4);
        let mut rng = Pcg64::seed_from(5);
        let msg = c.compress(&[0.0; 4], &mut rng);
        assert_eq!(msg.nnz(), 0);
    }

    #[test]
    #[should_panic(expected = "momentum dim")]
    fn ssdm_dim_mismatch_rejected() {
        let mut c = SsdmCompressor::new(0.9, 4);
        let mut rng = Pcg64::seed_from(6);
        c.compress(&[0.0; 5], &mut rng);
    }
}
