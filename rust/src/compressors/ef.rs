//! Worker-side error feedback (EF-signSGD; Karimireddy et al. 2019, Zheng
//! et al. 2019): compress `g + e`, then update the residual
//! `e ← g + e − decode(Q(g + e))`.
//!
//! This is the mechanism the paper argues is *incompatible with worker
//! sampling* — the residual lives on the worker across rounds, so a worker
//! that skips rounds replays stale error. We implement it (a) as a baseline
//! and (b) so the integration tests can demonstrate exactly that failure
//! mode; the coordinator refuses to pair it with partial participation
//! unless explicitly overridden.

use super::{CompressedGrad, Compressor};
use crate::coding::cost::CostModel;
use crate::util::rng::Pcg64;

/// Error-feedback wrapper around any inner compressor.
pub struct WorkerEfCompressor {
    inner: Box<dyn Compressor>,
    /// Per-worker residual `e^{(t)}`.
    residual: Vec<f32>,
    /// Scratch buffer for `g + e` (avoids an allocation per round).
    corrected: Vec<f32>,
}

impl WorkerEfCompressor {
    pub fn new(inner: Box<dyn Compressor>, dim: usize) -> Self {
        Self { inner, residual: vec![0.0; dim], corrected: vec![0.0; dim] }
    }

    /// Current residual (for tests / diagnostics).
    pub fn residual(&self) -> &[f32] {
        &self.residual
    }
}

impl Compressor for WorkerEfCompressor {
    fn compress(&mut self, g: &[f32], rng: &mut Pcg64) -> CompressedGrad {
        assert_eq!(
            g.len(),
            self.residual.len(),
            "EF residual dim {} != gradient dim {}",
            self.residual.len(),
            g.len()
        );
        self.corrected.clear();
        self.corrected.extend(g.iter().zip(&self.residual).map(|(a, b)| a + b));
        let msg = self.inner.compress(&self.corrected, rng);
        // e ← (g + e) − decoded(msg)
        match &msg {
            CompressedGrad::Ternary { pack, .. } => {
                // Start from e = (g + e), then subtract the decoded value at
                // each transmitted coordinate — O(nnz) instead of O(d).
                self.residual.copy_from_slice(&self.corrected);
                let s = pack.scale();
                let residual = &mut self.residual;
                pack.for_each_nonzero(|i, q| residual[i] -= s * q as f32);
            }
            CompressedGrad::Dense { v, .. } => {
                for ((e, &c), &vi) in
                    self.residual.iter_mut().zip(&self.corrected).zip(v.iter())
                {
                    *e = c - vi;
                }
            }
        }
        msg
    }

    fn name(&self) -> String {
        format!("ef-{}", self.inner.name())
    }

    fn requires_worker_state(&self) -> bool {
        true
    }

    fn cost_model(&self) -> CostModel {
        self.inner.cost_model()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::{ScaledSignCompressor, SignCompressor, TopKCompressor};

    #[test]
    fn residual_identity_holds() {
        // After each step: e' = g + e − decode(msg), exactly.
        let mut ef = WorkerEfCompressor::new(Box::new(ScaledSignCompressor), 4);
        let mut rng = Pcg64::seed_from(1);
        let g1 = vec![1.0, -2.0, 0.5, 0.0];
        let m1 = ef.compress(&g1, &mut rng);
        let d1 = m1.to_dense();
        for i in 0..4 {
            assert!((ef.residual()[i] - (g1[i] - d1[i])).abs() < 1e-6);
        }
        let g2 = vec![0.3, 0.3, -0.3, 1.0];
        let e_before: Vec<f32> = ef.residual().to_vec();
        let m2 = ef.compress(&g2, &mut rng);
        let d2 = m2.to_dense();
        for i in 0..4 {
            let want = g2[i] + e_before[i] - d2[i];
            assert!((ef.residual()[i] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn ef_scaled_sign_residual_stays_bounded() {
        // The contraction property of the α-approximate compressor keeps
        // the residual norm bounded on a stationary gradient stream.
        let dim = 128;
        let mut ef = WorkerEfCompressor::new(Box::new(ScaledSignCompressor), dim);
        let mut rng = Pcg64::seed_from(2);
        let mut data_rng = Pcg64::seed_from(3);
        let mut max_norm = 0.0f32;
        for _ in 0..200 {
            let mut g = vec![0.0; dim];
            data_rng.fill_normal(&mut g, 0.0, 1.0);
            ef.compress(&g, &mut rng);
            let n = crate::util::l2_norm(ef.residual());
            max_norm = max_norm.max(n);
        }
        // ‖e‖ should stay well below the cumulative gradient norm (~200·√d).
        assert!(max_norm < 60.0, "residual blew up: {max_norm}");
    }

    #[test]
    fn ef_topk_transmits_stale_mass_eventually() {
        // A coordinate that is always small-but-nonzero accumulates in the
        // residual until Top-1 selects it — the defining EF behaviour.
        let mut ef = WorkerEfCompressor::new(Box::new(TopKCompressor { k: 1 }), 2);
        let mut rng = Pcg64::seed_from(4);
        let g = vec![1.0f32, 0.3];
        let mut coord1_sent = false;
        for _ in 0..10 {
            let d = ef.compress(&g, &mut rng).to_dense();
            if d[1] != 0.0 {
                coord1_sent = true;
                break;
            }
        }
        assert!(coord1_sent, "EF never flushed the small coordinate");
    }

    #[test]
    fn marks_stateful() {
        let ef = WorkerEfCompressor::new(Box::new(SignCompressor), 3);
        assert!(ef.requires_worker_state());
        assert_eq!(ef.name(), "ef-sign");
    }

    #[test]
    #[should_panic(expected = "EF residual dim")]
    fn dim_mismatch_rejected() {
        let mut ef = WorkerEfCompressor::new(Box::new(SignCompressor), 3);
        let mut rng = Pcg64::seed_from(5);
        ef.compress(&[1.0; 4], &mut rng);
    }
}
