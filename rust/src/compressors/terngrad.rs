//! TernGrad (Wen et al. 2017):
//! `ternarize(g) = s_t · sign(g) · ξ(g, s_t)` with `s_t = ‖g‖∞` and
//! `P(ξ_i = 1) = |g_i| / s_t` — an unbiased ternary quantizer.
//!
//! The paper's Remark 2 reads TernGrad as a *scaled* sparsign with
//! `B_i = 1/maxₘ‖g_m‖∞`: the keep-probability is magnitude-proportional,
//! but the transmitted values are rescaled by `s_t` to preserve
//! unbiasedness (which requires sharing the norm — the re-scaling-attack
//! surface sparsign avoids). We implement the per-worker scale
//! `s_t = ‖g_m‖∞`; the cross-worker-max "magnitude sharing protocol"
//! variant only changes the scalar and is covered by the aggregation tests.

use super::{ternary_bits, CompressedGrad, Compressor, PackedBuilder, PackedTernary};
use crate::coding::cost::CostModel;
use crate::util::linf_norm;
use crate::util::rng::{bernoulli_threshold, Pcg64, U32Stream};

/// TernGrad compressor.
#[derive(Clone, Copy, Debug)]
pub struct TernGradCompressor;

impl Compressor for TernGradCompressor {
    fn compress(&mut self, g: &[f32], rng: &mut Pcg64) -> CompressedGrad {
        let st = linf_norm(g);
        if st == 0.0 || g.is_empty() {
            return CompressedGrad::ternary(PackedTernary::zeros(g.len(), 0.0), 32.0);
        }
        let inv = 1.0 / st;
        let mut pk = PackedBuilder::new(g.len());
        let mut u = U32Stream::new(rng);
        for &gi in g.iter() {
            let thr = bernoulli_threshold(gi.abs() * inv); // p ≤ 1 by construction
            pk.push(if u.bernoulli(thr) {
                if gi > 0.0 {
                    1
                } else {
                    -1
                }
            } else {
                0
            });
        }
        let pack = pk.finish(st);
        let bits = ternary_bits(g.len(), pack.nnz(), true);
        CompressedGrad::ternary(pack, bits)
    }

    fn name(&self) -> String {
        "terngrad".into()
    }

    fn cost_model(&self) -> CostModel {
        CostModel::SparseTernary
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unbiased() {
        let g = vec![0.5f32, -1.0, 0.25, 0.0];
        let mut c = TernGradCompressor;
        let mut rng = Pcg64::seed_from(1);
        let trials = 60_000;
        let mut sums = vec![0.0f64; 4];
        for _ in 0..trials {
            for (s, v) in sums.iter_mut().zip(c.compress(&g, &mut rng).to_dense()) {
                *s += v as f64;
            }
        }
        for (i, &s) in sums.iter().enumerate() {
            let mean = s / trials as f64;
            assert!((mean - g[i] as f64).abs() < 0.015, "coord {i}: {mean} vs {}", g[i]);
        }
    }

    #[test]
    fn max_coordinate_always_kept() {
        let g = vec![0.1f32, -2.0, 0.3];
        let mut c = TernGradCompressor;
        let mut rng = Pcg64::seed_from(2);
        for _ in 0..200 {
            let d = c.compress(&g, &mut rng).to_dense();
            assert_eq!(d[1], -2.0); // p = |g|/‖g‖∞ = 1 for the max coord
        }
    }

    #[test]
    fn zero_gradient() {
        let mut c = TernGradCompressor;
        let mut rng = Pcg64::seed_from(3);
        let msg = c.compress(&[0.0; 8], &mut rng);
        assert_eq!(msg.nnz(), 0);
        assert_eq!(msg.bits(), 32.0);
    }

    #[test]
    fn relation_to_sparsign_remark2() {
        // TernGrad keep-probabilities equal sparsign's with B = 1/‖g‖∞
        // (Remark 2). Compare empirical densities.
        use crate::compressors::SparsignCompressor;
        let mut data_rng = Pcg64::seed_from(4);
        let mut g = vec![0.0; 2048];
        data_rng.fill_normal(&mut g, 0.0, 0.3);
        let b = 1.0 / linf_norm(&g);
        let mut tern = TernGradCompressor;
        let mut spar = SparsignCompressor { budget: b };
        let mut r1 = Pcg64::seed_from(5);
        let mut r2 = Pcg64::seed_from(6);
        let reps = 64;
        let nt: usize = (0..reps).map(|_| tern.compress(&g, &mut r1).nnz()).sum();
        let ns: usize = (0..reps).map(|_| spar.compress(&g, &mut r2).nnz()).sum();
        let (nt, ns) = (nt as f64 / reps as f64, ns as f64 / reps as f64);
        assert!((nt - ns).abs() < 0.05 * nt.max(ns), "terngrad {nt} sparsign {ns}");
    }
}
