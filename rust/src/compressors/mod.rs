//! Gradient compressors: the paper's `sparsign` (Definition 1) and every
//! baseline from §6 / Appendix B, with exact per-message bit accounting.
//!
//! All compressors map a dense gradient `g ∈ ℝᵈ` to a [`CompressedGrad`]
//! message. Ternary-valued messages carry `{-1,0,+1}` codes plus an
//! optional scale; their uplink cost follows the paper's Golomb accounting
//! (eq. (12), implemented in [`crate::coding`]). Stateless compressors are
//! the point of the paper — only the explicitly-marked error-feedback
//! wrapper keeps worker-side state, and the coordinator refuses to combine
//! it with worker sampling (the exact failure mode the paper fixes).

mod ef;
mod qsgd;
mod sign;
mod sparse;
mod sparsign;
mod ssdm;
mod terngrad;

pub use ef::WorkerEfCompressor;
pub use qsgd::{NormKind, QsgdCompressor};
pub use sign::{NoisySignCompressor, ScaledSignCompressor, SignCompressor};
pub use sparse::{RandKCompressor, StcCompressor, ThresholdVCompressor, TopKCompressor};
pub use sparsign::{SparsignAutoCompressor, SparsignCompressor};
pub use ssdm::{SsdmCompressor, StoSignCompressor};
pub use terngrad::TernGradCompressor;

use crate::coding::cost::CostModel;
use crate::util::rng::Pcg64;

/// A compressed gradient message plus its exact uplink cost in bits.
#[derive(Clone, Debug)]
pub enum CompressedGrad {
    /// Ternary codes `q[i] ∈ {-1,0,+1}`; decoded value is `scale * q[i]`.
    /// `bits` is the Golomb-accounted message size.
    Ternary { q: Vec<i8>, scale: f32, bits: f64 },
    /// Dense float message (identity / multi-level QSGD decode).
    Dense { v: Vec<f32>, bits: f64 },
}

impl CompressedGrad {
    /// Dimension of the underlying gradient.
    pub fn dim(&self) -> usize {
        match self {
            CompressedGrad::Ternary { q, .. } => q.len(),
            CompressedGrad::Dense { v, .. } => v.len(),
        }
    }

    /// Message size in bits.
    pub fn bits(&self) -> f64 {
        match self {
            CompressedGrad::Ternary { bits, .. } | CompressedGrad::Dense { bits, .. } => *bits,
        }
    }

    /// Number of non-zero coordinates.
    pub fn nnz(&self) -> usize {
        match self {
            CompressedGrad::Ternary { q, .. } => q.iter().filter(|&&x| x != 0).count(),
            CompressedGrad::Dense { v, .. } => v.iter().filter(|&&x| x != 0.0).count(),
        }
    }

    /// Accumulate the decoded message into `acc` (server-side aggregation
    /// hot path; the ternary arm is branch-light on purpose — see §Perf).
    pub fn add_into(&self, acc: &mut [f32]) {
        match self {
            CompressedGrad::Ternary { q, scale, .. } => {
                debug_assert_eq!(acc.len(), q.len());
                let s = *scale;
                for (a, &qi) in acc.iter_mut().zip(q.iter()) {
                    *a += s * qi as f32;
                }
            }
            CompressedGrad::Dense { v, .. } => {
                debug_assert_eq!(acc.len(), v.len());
                for (a, &vi) in acc.iter_mut().zip(v.iter()) {
                    *a += vi;
                }
            }
        }
    }

    /// Decode to a dense vector.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.dim()];
        self.add_into(&mut out);
        out
    }
}

/// Worker-side gradient compressor. Takes `&mut self` so the (explicitly
/// stateful) error-feedback baseline fits the same interface; all paper
/// algorithms keep the implementation stateless.
pub trait Compressor: Send {
    /// Compress `g`, drawing any stochasticity from `rng`.
    fn compress(&mut self, g: &[f32], rng: &mut Pcg64) -> CompressedGrad;

    /// Display name used in tables.
    fn name(&self) -> String;

    /// True iff the compressor keeps per-worker state across rounds
    /// (incompatible with worker sampling — Algorithm 1's engine asserts
    /// this is false when `participation < 1`).
    fn requires_worker_state(&self) -> bool {
        false
    }

    /// Cost model used for the compressor's messages (for documentation /
    /// cross-checks; the per-message `bits` field is authoritative).
    fn cost_model(&self) -> CostModel;
}

/// Config-level compressor selection; `build()` instantiates a fresh
/// (per-worker) compressor object.
#[derive(Clone, Debug, PartialEq)]
pub enum CompressorKind {
    /// signSGD (Bernstein et al. 2018): dense ±1.
    Sign,
    /// Scaled signSGD (Karimireddy et al. 2019): (‖g‖₁/d)·sign(g).
    ScaledSign,
    /// Noisy signSGD (Chen et al. 2020a): sign(g + N(0, σ²)).
    NoisySign { noise_std: f32 },
    /// QSGD (Alistarh et al. 2017) with `levels` = s and a norm choice.
    Qsgd { levels: u32, norm: NormKind },
    /// TernGrad (Wen et al. 2017).
    TernGrad,
    /// The paper's sparsign (Definition 1) with budget B.
    Sparsign { budget: f32 },
    /// Auto-density sparsign (Remark 7 budget protocol): B chosen per
    /// message so the expected density equals `target_density`.
    SparsignAuto { target_density: f32 },
    /// sto-SIGN (Jin et al. 2020): stochastic sign with scale b.
    StoSign { b: f32 },
    /// SSDM (Safaryan & Richtárik 2021): worker momentum + stochastic
    /// sign. Stateful — incompatible with worker sampling.
    Ssdm { beta: f32 },
    /// Top-k sparsification (Alistarh et al. 2018).
    TopK { k: usize },
    /// Random-k sparsification (Stich et al. 2018).
    RandK { k: usize },
    /// Threshold-v sparsification (Lin et al. 2018; Sahu et al. 2021).
    ThresholdV { v: f32 },
    /// Sparse ternary compression (Sattler et al. 2019a).
    Stc { k: usize },
    /// Worker-side error feedback around an inner compressor
    /// (EF-signSGD, Karimireddy et al. 2019 / Zheng et al. 2019).
    WorkerEf(Box<CompressorKind>),
    /// No compression (32-bit floats) — D-SGD reference.
    Identity,
}

impl CompressorKind {
    /// Instantiate a per-worker compressor.
    pub fn build(&self, dim: usize) -> Box<dyn Compressor> {
        match self {
            CompressorKind::Sign => Box::new(SignCompressor),
            CompressorKind::ScaledSign => Box::new(ScaledSignCompressor),
            CompressorKind::NoisySign { noise_std } => {
                Box::new(NoisySignCompressor { noise_std: *noise_std })
            }
            CompressorKind::Qsgd { levels, norm } => {
                Box::new(QsgdCompressor { levels: *levels, norm: *norm })
            }
            CompressorKind::TernGrad => Box::new(TernGradCompressor),
            CompressorKind::Sparsign { budget } => {
                Box::new(SparsignCompressor { budget: *budget })
            }
            CompressorKind::SparsignAuto { target_density } => {
                Box::new(SparsignAutoCompressor { target_density: *target_density })
            }
            CompressorKind::StoSign { b } => Box::new(StoSignCompressor { b: *b }),
            CompressorKind::Ssdm { beta } => Box::new(SsdmCompressor::new(*beta, dim)),
            CompressorKind::TopK { k } => Box::new(TopKCompressor { k: *k }),
            CompressorKind::RandK { k } => Box::new(RandKCompressor { k: *k }),
            CompressorKind::ThresholdV { v } => Box::new(ThresholdVCompressor { v: *v }),
            CompressorKind::Stc { k } => Box::new(StcCompressor { k: *k }),
            CompressorKind::WorkerEf(inner) => {
                Box::new(WorkerEfCompressor::new(inner.build(dim), dim))
            }
            CompressorKind::Identity => Box::new(IdentityCompressor),
        }
    }

    /// Table-row label.
    pub fn label(&self) -> String {
        match self {
            CompressorKind::Sign => "signSGD".into(),
            CompressorKind::ScaledSign => "Scaled signSGD".into(),
            CompressorKind::NoisySign { .. } => "Noisy signSGD".into(),
            CompressorKind::Qsgd { levels: 1, norm: NormKind::L2 } => {
                "1-bit L2 norm QSGD".into()
            }
            CompressorKind::Qsgd { levels: 1, norm: NormKind::Linf } => {
                "1-bit Linf norm QSGD".into()
            }
            CompressorKind::Qsgd { levels, .. } => format!("QSGD(s={levels})"),
            CompressorKind::TernGrad => "TernGrad".into(),
            CompressorKind::Sparsign { budget } => format!("sparsignSGD(B={budget})"),
            CompressorKind::SparsignAuto { target_density } => {
                format!("sparsignSGD-auto(p={target_density})")
            }
            CompressorKind::StoSign { b } => format!("sto-SIGNSGD(b={b})"),
            CompressorKind::Ssdm { beta } => format!("SSDM(beta={beta})"),
            CompressorKind::TopK { k } => format!("Top-{k}"),
            CompressorKind::RandK { k } => format!("Random-{k}"),
            CompressorKind::ThresholdV { v } => format!("Threshold-{v}"),
            CompressorKind::Stc { k } => format!("STC(k={k})"),
            CompressorKind::WorkerEf(inner) => format!("EF-{}", inner.label()),
            CompressorKind::Identity => "D-SGD (fp32)".into(),
        }
    }
}

/// No-op compressor: transmits raw f32 coordinates.
pub struct IdentityCompressor;

impl Compressor for IdentityCompressor {
    fn compress(&mut self, g: &[f32], _rng: &mut Pcg64) -> CompressedGrad {
        CompressedGrad::Dense { v: g.to_vec(), bits: 32.0 * g.len() as f64 }
    }

    fn name(&self) -> String {
        "identity".into()
    }

    fn cost_model(&self) -> CostModel {
        CostModel::Dense { bits_per_coord: 32.0, overhead_bits: 0.0 }
    }
}

/// Shared helper: Golomb-accounted bits for a ternary vector with `nnz`
/// non-zeros (+32 bits when a float scale accompanies the message).
pub(crate) fn ternary_bits(d: usize, nnz: usize, with_scale: bool) -> f64 {
    let base = CostModel::SparseTernary.bits(d, nnz);
    if with_scale {
        base + 32.0
    } else {
        base
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_build_and_label() {
        let kinds = vec![
            CompressorKind::Sign,
            CompressorKind::ScaledSign,
            CompressorKind::NoisySign { noise_std: 0.1 },
            CompressorKind::Qsgd { levels: 1, norm: NormKind::L2 },
            CompressorKind::Qsgd { levels: 1, norm: NormKind::Linf },
            CompressorKind::Qsgd { levels: 255, norm: NormKind::L2 },
            CompressorKind::TernGrad,
            CompressorKind::Sparsign { budget: 1.0 },
            CompressorKind::TopK { k: 4 },
            CompressorKind::RandK { k: 4 },
            CompressorKind::ThresholdV { v: 0.1 },
            CompressorKind::Stc { k: 4 },
            CompressorKind::WorkerEf(Box::new(CompressorKind::Sign)),
            CompressorKind::Identity,
        ];
        let g: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) / 64.0).collect();
        for kind in kinds {
            let mut c = kind.build(g.len());
            let mut rng = Pcg64::seed_from(1);
            let msg = c.compress(&g, &mut rng);
            assert_eq!(msg.dim(), g.len(), "{}", kind.label());
            assert!(msg.bits() >= 0.0);
            assert!(!kind.label().is_empty());
        }
    }

    #[test]
    fn identity_roundtrips_exactly() {
        let g = vec![1.5, -2.25, 0.0, 3.0];
        let mut c = IdentityCompressor;
        let mut rng = Pcg64::seed_from(2);
        let msg = c.compress(&g, &mut rng);
        assert_eq!(msg.to_dense(), g);
        assert_eq!(msg.bits(), 128.0);
        assert_eq!(msg.nnz(), 3);
    }

    #[test]
    fn add_into_accumulates() {
        let msg = CompressedGrad::Ternary { q: vec![1, -1, 0, 1], scale: 2.0, bits: 0.0 };
        let mut acc = vec![1.0; 4];
        msg.add_into(&mut acc);
        assert_eq!(acc, vec![3.0, -1.0, 1.0, 3.0]);
        assert_eq!(msg.nnz(), 3);
    }

    #[test]
    fn only_ef_requires_state() {
        let g_dim = 8;
        let stateless = [
            CompressorKind::Sign,
            CompressorKind::Sparsign { budget: 1.0 },
            CompressorKind::TernGrad,
            CompressorKind::Qsgd { levels: 1, norm: NormKind::L2 },
        ];
        for k in stateless {
            assert!(!k.build(g_dim).requires_worker_state(), "{}", k.label());
        }
        let ef = CompressorKind::WorkerEf(Box::new(CompressorKind::Sign)).build(g_dim);
        assert!(ef.requires_worker_state());
    }
}
