//! Gradient compressors: the paper's `sparsign` (Definition 1) and every
//! baseline from §6 / Appendix B, with exact per-message bit accounting.
//!
//! All compressors map a dense gradient `g ∈ ℝᵈ` to a [`CompressedGrad`]
//! message. Ternary-valued messages carry `{-1,0,+1}` codes plus an
//! optional scale; their uplink cost follows the paper's Golomb accounting
//! (eq. (12), implemented in [`crate::coding`]). Stateless compressors are
//! the point of the paper — only the explicitly-marked error-feedback
//! wrapper keeps worker-side state, and the coordinator refuses to combine
//! it with worker sampling (the exact failure mode the paper fixes).
//!
//! Ternary payloads are stored as [`PackedTernary`] — two `u64` bitplanes
//! (support mask + sign) instead of a `Vec<i8>` — 2 bits/coordinate, a 4×
//! memory reduction over i8 codes (16× over the f32 each message was
//! widened to server-side) that lets the server aggregate with
//! word-parallel vote counting (DESIGN.md §8) instead of per-coordinate
//! i8→f32 widening.

mod ef;
mod qsgd;
mod sign;
mod sparse;
mod sparsign;
mod ssdm;
mod terngrad;

pub use ef::WorkerEfCompressor;
pub use qsgd::{NormKind, QsgdCompressor};
pub use sign::{NoisySignCompressor, ScaledSignCompressor, SignCompressor};
pub use sparse::{RandKCompressor, StcCompressor, ThresholdVCompressor, TopKCompressor};
pub use sparsign::{SparsignAutoCompressor, SparsignCompressor};
pub use ssdm::{SsdmCompressor, StoSignCompressor};
pub use terngrad::TernGradCompressor;

use crate::coding::cost::CostModel;
use crate::util::rng::Pcg64;

/// A ternary vector `q ∈ {-1,0,+1}ᵈ` packed into two bitplanes of 64
/// coordinates per word:
///
/// * `mask` — bit `i` set ⇔ `q[i] ≠ 0` (the sparse support);
/// * `sign` — bit `i` set ⇔ `q[i] = −1` (only meaningful under `mask`).
///
/// The non-zero count and the decode scale are cached at construction so
/// the per-message bit accounting (`nnz` is consulted for every message)
/// never rescans the payload. Invariant: `sign ⊆ mask`.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedTernary {
    dim: usize,
    nnz: usize,
    scale: f32,
    mask: Vec<u64>,
    sign: Vec<u64>,
}

impl PackedTernary {
    /// Coordinates per bitplane word.
    pub const LANES: usize = 64;

    /// Number of `u64` words needed per bitplane for a `dim`-vector.
    #[inline]
    pub fn words(dim: usize) -> usize {
        (dim + 63) >> 6
    }

    /// The all-zero message (empty support).
    pub fn zeros(dim: usize, scale: f32) -> Self {
        let words = Self::words(dim);
        Self { dim, nnz: 0, scale, mask: vec![0; words], sign: vec![0; words] }
    }

    /// Reset to an all-zero `dim`-message with `scale`, reusing the word
    /// storage. Capacity grows monotonically and never shrinks, so a
    /// message buffer cycled through same-shape rounds stops touching the
    /// heap after its first use — the streaming engine's per-thread
    /// message scratch relies on this (`tests/zero_alloc_round.rs`).
    pub fn reset(&mut self, dim: usize, scale: f32) {
        let words = Self::words(dim);
        self.mask.clear();
        self.mask.resize(words, 0);
        self.sign.clear();
        self.sign.resize(words, 0);
        self.dim = dim;
        self.nnz = 0;
        self.scale = scale;
    }

    /// Reset to a fresh `dim`-message and return a streaming writer over
    /// it — the zero-allocation twin of [`PackedBuilder`]. The writer's
    /// `finish` stamps the decode scale.
    pub fn start(&mut self, dim: usize) -> PackedWriter<'_> {
        self.reset(dim, 1.0);
        PackedWriter { pack: self, len: 0 }
    }

    /// Pack an explicit code vector (`q[i] ∈ {-1,0,+1}`).
    pub fn from_codes(q: &[i8], scale: f32) -> Self {
        let mut b = PackedBuilder::new(q.len());
        for &c in q {
            b.push(c);
        }
        b.finish(scale)
    }

    /// Dense sign message with the `sign(0) = +1` convention: every
    /// coordinate is non-zero (`mask` all-ones), `sign` bit set where
    /// `g[i] < 0`. One word of output per 64 input floats — the signSGD
    /// and scaled-sign fast path.
    pub fn dense_signs(g: &[f32], scale: f32) -> Self {
        let mut pack = Self::zeros(0, scale);
        pack.fill_dense_signs(g, scale);
        pack
    }

    /// In-place [`Self::dense_signs`] over a reusable message buffer.
    /// Unlike [`Self::reset`] this never pre-zeroes retained storage —
    /// the sign loop overwrites every live word — so the dense-sign hot
    /// path does a single pass over the planes.
    pub fn fill_dense_signs(&mut self, g: &[f32], scale: f32) {
        let words = Self::words(g.len());
        self.mask.resize(words, 0);
        self.sign.resize(words, 0);
        for (w, chunk) in g.chunks(Self::LANES).enumerate() {
            let mut m = 0u64;
            let mut s = 0u64;
            for (j, &x) in chunk.iter().enumerate() {
                m |= 1u64 << j;
                if x < 0.0 {
                    s |= 1u64 << j;
                }
            }
            self.mask[w] = m;
            self.sign[w] = s;
        }
        self.dim = g.len();
        self.nnz = g.len();
        self.scale = scale;
    }

    /// Dimension `d`.
    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Cached non-zero count.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Decode scale: the transmitted value at a non-zero coordinate is
    /// `scale * q[i]`.
    #[inline]
    pub fn scale(&self) -> f32 {
        self.scale
    }

    /// The support bitplane.
    #[inline]
    pub fn mask_words(&self) -> &[u64] {
        &self.mask
    }

    /// The sign bitplane (`1` ⇒ negative).
    #[inline]
    pub fn sign_words(&self) -> &[u64] {
        &self.sign
    }

    /// Ternary code at coordinate `i`.
    #[inline]
    pub fn get(&self, i: usize) -> i8 {
        debug_assert!(i < self.dim);
        let w = i >> 6;
        let b = i & 63;
        if (self.mask[w] >> b) & 1 == 0 {
            0
        } else if (self.sign[w] >> b) & 1 == 1 {
            -1
        } else {
            1
        }
    }

    /// Overwrite coordinate `i` with `code` (maintains `nnz`). Used by the
    /// index-addressed compressors (STC); streaming emitters should prefer
    /// [`PackedBuilder::push`].
    pub fn set(&mut self, i: usize, code: i8) {
        debug_assert!(i < self.dim);
        debug_assert!((-1..=1).contains(&code));
        let w = i >> 6;
        let bit = 1u64 << (i & 63);
        if self.mask[w] & bit != 0 {
            self.nnz -= 1;
        }
        self.mask[w] &= !bit;
        self.sign[w] &= !bit;
        if code != 0 {
            self.mask[w] |= bit;
            if code < 0 {
                self.sign[w] |= bit;
            }
            self.nnz += 1;
        }
    }

    /// Unpack to an explicit code vector.
    pub fn to_codes(&self) -> Vec<i8> {
        let mut out = vec![0i8; self.dim];
        self.for_each_nonzero(|i, s| out[i] = s);
        out
    }

    /// Visit every non-zero coordinate as `(index, ±1)` in ascending index
    /// order, skipping empty words (the sparse-message fast path).
    #[inline]
    pub fn for_each_nonzero(&self, mut f: impl FnMut(usize, i8)) {
        for (w, (&m, &s)) in self.mask.iter().zip(&self.sign).enumerate() {
            let mut bits = m;
            let base = w << 6;
            while bits != 0 {
                let j = bits.trailing_zeros() as usize;
                f(base + j, if (s >> j) & 1 == 1 { -1 } else { 1 });
                bits &= bits - 1;
            }
        }
    }

    /// Accumulate the decoded message into `acc`: `acc[i] += scale·q[i]`.
    pub fn add_into(&self, acc: &mut [f32]) {
        debug_assert_eq!(acc.len(), self.dim);
        let s = self.scale;
        self.for_each_nonzero(|i, q| acc[i] += s * q as f32);
    }

    /// Rebuild this message from decoded bitplane words — the wire-codec
    /// ingest path (`net/wire.rs`). The iterator yields `(mask, sign)`
    /// word pairs in plane order. Every construction invariant is
    /// re-validated against untrusted input: the word count must match
    /// `dim`, mask bits past `dim` must be clear, `sign ⊆ mask` must
    /// hold, and the cached `nnz` is recomputed from the planes rather
    /// than trusted from the peer. Storage is reused, so decoding a
    /// same-shape stream into one scratch message allocates nothing
    /// after warm-up.
    pub fn load_words<I>(&mut self, dim: usize, scale: f32, words: I) -> Result<(), &'static str>
    where
        I: ExactSizeIterator<Item = (u64, u64)>,
    {
        let need = Self::words(dim);
        if words.len() != need {
            return Err("bitplane word count does not match dim");
        }
        if !scale.is_finite() {
            return Err("non-finite decode scale");
        }
        self.mask.clear();
        self.sign.clear();
        self.mask.reserve(need);
        self.sign.reserve(need);
        let mut nnz = 0usize;
        for (i, (m, s)) in words.enumerate() {
            let valid = if i + 1 == need && dim & 63 != 0 {
                (1u64 << (dim & 63)) - 1
            } else {
                !0u64
            };
            if m & !valid != 0 {
                self.reset(0, 1.0);
                return Err("mask bits beyond dim");
            }
            if s & !m != 0 {
                self.reset(0, 1.0);
                return Err("sign bit outside the support mask");
            }
            nnz += m.count_ones() as usize;
            self.mask.push(m);
            self.sign.push(s);
        }
        self.dim = dim;
        self.nnz = nnz;
        self.scale = scale;
        Ok(())
    }
}

/// Append the next coordinate's code (`-1`, `0`, or `+1`) to a packed
/// message under construction — the single emission primitive shared by
/// [`PackedBuilder`] (owning) and [`PackedWriter`] (borrowing).
#[inline]
fn push_code(pack: &mut PackedTernary, len: &mut usize, code: i8) {
    debug_assert!(*len < pack.dim, "push past dim {}", pack.dim);
    debug_assert!((-1..=1).contains(&code));
    if code != 0 {
        let w = *len >> 6;
        let bit = 1u64 << (*len & 63);
        pack.mask[w] |= bit;
        if code < 0 {
            pack.sign[w] |= bit;
        }
        pack.nnz += 1;
    }
    *len += 1;
}

/// Streaming constructor for [`PackedTernary`]: compressors emit one code
/// per coordinate in order and never materialize a `Vec<i8>`.
pub struct PackedBuilder {
    pack: PackedTernary,
    len: usize,
}

impl PackedBuilder {
    pub fn new(dim: usize) -> Self {
        Self { pack: PackedTernary::zeros(dim, 1.0), len: 0 }
    }

    /// Append the next coordinate's code (`-1`, `0`, or `+1`).
    #[inline]
    pub fn push(&mut self, code: i8) {
        push_code(&mut self.pack, &mut self.len, code);
    }

    /// Non-zeros emitted so far.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.pack.nnz
    }

    pub fn finish(mut self, scale: f32) -> PackedTernary {
        assert_eq!(
            self.len, self.pack.dim,
            "PackedBuilder finished after {} of {} coordinates",
            self.len, self.pack.dim
        );
        self.pack.scale = scale;
        self.pack
    }
}

/// [`PackedBuilder`]'s zero-allocation twin: streams codes into a
/// caller-owned [`PackedTernary`] (obtained via [`PackedTernary::start`]),
/// so steady-state compression reuses one message buffer per thread.
pub struct PackedWriter<'a> {
    pack: &'a mut PackedTernary,
    len: usize,
}

impl PackedWriter<'_> {
    /// Append the next coordinate's code (`-1`, `0`, or `+1`).
    #[inline]
    pub fn push(&mut self, code: i8) {
        push_code(self.pack, &mut self.len, code);
    }

    /// Non-zeros emitted so far.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.pack.nnz
    }

    /// Seal the message: asserts every coordinate was emitted and stamps
    /// the decode scale.
    pub fn finish(self, scale: f32) {
        assert_eq!(
            self.len, self.pack.dim,
            "PackedWriter finished after {} of {} coordinates",
            self.len, self.pack.dim
        );
        self.pack.scale = scale;
    }
}

/// A compressed gradient message plus its exact uplink cost in bits.
/// `PartialEq` compares payload, cached counts and bit cost exactly —
/// the wire codec's round-trip tests rely on it.
#[derive(Clone, Debug, PartialEq)]
pub enum CompressedGrad {
    /// Ternary codes in packed bitplanes; decoded value is
    /// `pack.scale() * q[i]`. `bits` is the Golomb-accounted message size.
    Ternary { pack: PackedTernary, bits: f64 },
    /// Dense float message (identity / multi-level QSGD decode) with the
    /// non-zero count cached at construction.
    Dense { v: Vec<f32>, nnz: usize, bits: f64 },
}

impl CompressedGrad {
    /// Ternary message from packed bitplanes.
    pub fn ternary(pack: PackedTernary, bits: f64) -> Self {
        CompressedGrad::Ternary { pack, bits }
    }

    /// Ternary message from an explicit code vector (tests / interop).
    pub fn ternary_from_codes(q: &[i8], scale: f32, bits: f64) -> Self {
        CompressedGrad::Ternary { pack: PackedTernary::from_codes(q, scale), bits }
    }

    /// Dense message; counts (and caches) the non-zeros once here.
    pub fn dense(v: Vec<f32>, bits: f64) -> Self {
        let nnz = v.iter().filter(|&&x| x != 0.0).count();
        CompressedGrad::Dense { v, nnz, bits }
    }

    /// Dense message with the non-zero count already known to the caller.
    pub fn dense_with_nnz(v: Vec<f32>, nnz: usize, bits: f64) -> Self {
        debug_assert_eq!(nnz, v.iter().filter(|&&x| x != 0.0).count());
        CompressedGrad::Dense { v, nnz, bits }
    }

    /// Dimension of the underlying gradient.
    pub fn dim(&self) -> usize {
        match self {
            CompressedGrad::Ternary { pack, .. } => pack.dim(),
            CompressedGrad::Dense { v, .. } => v.len(),
        }
    }

    /// Message size in bits.
    pub fn bits(&self) -> f64 {
        match self {
            CompressedGrad::Ternary { bits, .. } | CompressedGrad::Dense { bits, .. } => *bits,
        }
    }

    /// Number of non-zero coordinates (cached at construction — consulted
    /// per message by the bit-accounting ledger).
    pub fn nnz(&self) -> usize {
        match self {
            CompressedGrad::Ternary { pack, .. } => pack.nnz(),
            CompressedGrad::Dense { nnz, .. } => *nnz,
        }
    }

    /// Accumulate the decoded message into `acc` (server-side aggregation
    /// fallback path; the packed ternary arm skips empty words — see
    /// DESIGN.md §8).
    pub fn add_into(&self, acc: &mut [f32]) {
        match self {
            CompressedGrad::Ternary { pack, .. } => {
                debug_assert_eq!(acc.len(), pack.dim());
                pack.add_into(acc);
            }
            CompressedGrad::Dense { v, .. } => {
                debug_assert_eq!(acc.len(), v.len());
                for (a, &vi) in acc.iter_mut().zip(v.iter()) {
                    *a += vi;
                }
            }
        }
    }

    /// Decode to a dense vector.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.dim()];
        self.add_into(&mut out);
        out
    }
}

/// Worker-side gradient compressor. Takes `&mut self` so the (explicitly
/// stateful) error-feedback baseline fits the same interface; all paper
/// algorithms keep the implementation stateless.
pub trait Compressor: Send {
    /// Compress `g`, drawing any stochasticity from `rng`.
    fn compress(&mut self, g: &[f32], rng: &mut Pcg64) -> CompressedGrad;

    /// Compress `g` into a reusable packed-ternary message buffer — the
    /// accumulator-facing view the streaming round engine folds without
    /// ever materializing a [`CompressedGrad`]. Returns the message's bit
    /// cost when this compressor's messages are *always* packed ternary
    /// with decode scale exactly `1.0` (the streaming-aggregation
    /// predicate, see [`CompressorKind::streams_unit_ternary`]); the
    /// default returns `None` and callers fall back to
    /// [`Self::compress`]. Implementations must consume the same RNG
    /// stream as `compress` so the two paths replay bit-identically.
    fn compress_ternary_into(
        &mut self,
        g: &[f32],
        rng: &mut Pcg64,
        out: &mut PackedTernary,
    ) -> Option<f64> {
        let _ = (g, rng, out);
        None
    }

    /// Display name used in tables.
    fn name(&self) -> String;

    /// True iff the compressor keeps per-worker state across rounds
    /// (incompatible with worker sampling — Algorithm 1's engine asserts
    /// this is false when `participation < 1`).
    fn requires_worker_state(&self) -> bool {
        false
    }

    /// Cost model used for the compressor's messages (for documentation /
    /// cross-checks; the per-message `bits` field is authoritative).
    fn cost_model(&self) -> CostModel;
}

/// Config-level compressor selection; `build()` instantiates a fresh
/// (per-worker) compressor object.
#[derive(Clone, Debug, PartialEq)]
pub enum CompressorKind {
    /// signSGD (Bernstein et al. 2018): dense ±1.
    Sign,
    /// Scaled signSGD (Karimireddy et al. 2019): (‖g‖₁/d)·sign(g).
    ScaledSign,
    /// Noisy signSGD (Chen et al. 2020a): sign(g + N(0, σ²)).
    NoisySign { noise_std: f32 },
    /// QSGD (Alistarh et al. 2017) with `levels` = s and a norm choice.
    Qsgd { levels: u32, norm: NormKind },
    /// TernGrad (Wen et al. 2017).
    TernGrad,
    /// The paper's sparsign (Definition 1) with budget B.
    Sparsign { budget: f32 },
    /// Auto-density sparsign (Remark 7 budget protocol): B chosen per
    /// message so the expected density equals `target_density`.
    SparsignAuto { target_density: f32 },
    /// sto-SIGN (Jin et al. 2020): stochastic sign with scale b.
    StoSign { b: f32 },
    /// SSDM (Safaryan & Richtárik 2021): worker momentum + stochastic
    /// sign. Stateful — incompatible with worker sampling.
    Ssdm { beta: f32 },
    /// Top-k sparsification (Alistarh et al. 2018).
    TopK { k: usize },
    /// Random-k sparsification (Stich et al. 2018).
    RandK { k: usize },
    /// Threshold-v sparsification (Lin et al. 2018; Sahu et al. 2021).
    ThresholdV { v: f32 },
    /// Sparse ternary compression (Sattler et al. 2019a).
    Stc { k: usize },
    /// Worker-side error feedback around an inner compressor
    /// (EF-signSGD, Karimireddy et al. 2019 / Zheng et al. 2019).
    WorkerEf(Box<CompressorKind>),
    /// No compression (32-bit floats) — D-SGD reference.
    Identity,
}

impl CompressorKind {
    /// Instantiate a per-worker compressor.
    pub fn build(&self, dim: usize) -> Box<dyn Compressor> {
        match self {
            CompressorKind::Sign => Box::new(SignCompressor),
            CompressorKind::ScaledSign => Box::new(ScaledSignCompressor),
            CompressorKind::NoisySign { noise_std } => {
                Box::new(NoisySignCompressor { noise_std: *noise_std })
            }
            CompressorKind::Qsgd { levels, norm } => {
                Box::new(QsgdCompressor { levels: *levels, norm: *norm })
            }
            CompressorKind::TernGrad => Box::new(TernGradCompressor),
            CompressorKind::Sparsign { budget } => {
                Box::new(SparsignCompressor { budget: *budget })
            }
            CompressorKind::SparsignAuto { target_density } => {
                Box::new(SparsignAutoCompressor { target_density: *target_density })
            }
            CompressorKind::StoSign { b } => Box::new(StoSignCompressor { b: *b }),
            CompressorKind::Ssdm { beta } => Box::new(SsdmCompressor::new(*beta, dim)),
            CompressorKind::TopK { k } => Box::new(TopKCompressor { k: *k }),
            CompressorKind::RandK { k } => Box::new(RandKCompressor { k: *k }),
            CompressorKind::ThresholdV { v } => Box::new(ThresholdVCompressor { v: *v }),
            CompressorKind::Stc { k } => Box::new(StcCompressor { k: *k }),
            CompressorKind::WorkerEf(inner) => {
                Box::new(WorkerEfCompressor::new(inner.build(dim), dim))
            }
            CompressorKind::Identity => Box::new(IdentityCompressor),
        }
    }

    /// True when every message this compressor emits is packed ternary
    /// with decode scale exactly `1.0` — the static predicate under which
    /// the round engine streams votes into per-thread
    /// [`crate::coordinator::VoteAccumulator`]s instead of buffering the
    /// full message set (DESIGN.md §10). Kinds listed here must override
    /// [`Compressor::compress_ternary_into`].
    pub fn streams_unit_ternary(&self) -> bool {
        matches!(
            self,
            CompressorKind::Sign
                | CompressorKind::NoisySign { .. }
                | CompressorKind::Sparsign { .. }
                | CompressorKind::SparsignAuto { .. }
                | CompressorKind::StoSign { .. }
                | CompressorKind::Ssdm { .. }
        )
    }

    /// Table-row label.
    pub fn label(&self) -> String {
        match self {
            CompressorKind::Sign => "signSGD".into(),
            CompressorKind::ScaledSign => "Scaled signSGD".into(),
            CompressorKind::NoisySign { .. } => "Noisy signSGD".into(),
            CompressorKind::Qsgd { levels: 1, norm: NormKind::L2 } => {
                "1-bit L2 norm QSGD".into()
            }
            CompressorKind::Qsgd { levels: 1, norm: NormKind::Linf } => {
                "1-bit Linf norm QSGD".into()
            }
            CompressorKind::Qsgd { levels, .. } => format!("QSGD(s={levels})"),
            CompressorKind::TernGrad => "TernGrad".into(),
            CompressorKind::Sparsign { budget } => format!("sparsignSGD(B={budget})"),
            CompressorKind::SparsignAuto { target_density } => {
                format!("sparsignSGD-auto(p={target_density})")
            }
            CompressorKind::StoSign { b } => format!("sto-SIGNSGD(b={b})"),
            CompressorKind::Ssdm { beta } => format!("SSDM(beta={beta})"),
            CompressorKind::TopK { k } => format!("Top-{k}"),
            CompressorKind::RandK { k } => format!("Random-{k}"),
            CompressorKind::ThresholdV { v } => format!("Threshold-{v}"),
            CompressorKind::Stc { k } => format!("STC(k={k})"),
            CompressorKind::WorkerEf(inner) => format!("EF-{}", inner.label()),
            CompressorKind::Identity => "D-SGD (fp32)".into(),
        }
    }
}

/// No-op compressor: transmits raw f32 coordinates.
pub struct IdentityCompressor;

impl Compressor for IdentityCompressor {
    fn compress(&mut self, g: &[f32], _rng: &mut Pcg64) -> CompressedGrad {
        CompressedGrad::dense(g.to_vec(), 32.0 * g.len() as f64)
    }

    fn name(&self) -> String {
        "identity".into()
    }

    fn cost_model(&self) -> CostModel {
        CostModel::Dense { bits_per_coord: 32.0, overhead_bits: 0.0 }
    }
}

/// Shared helper: Golomb-accounted bits for a ternary vector with `nnz`
/// non-zeros (+32 bits when a float scale accompanies the message).
pub(crate) fn ternary_bits(d: usize, nnz: usize, with_scale: bool) -> f64 {
    let base = CostModel::SparseTernary.bits(d, nnz);
    if with_scale {
        base + 32.0
    } else {
        base
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_build_and_label() {
        let kinds = vec![
            CompressorKind::Sign,
            CompressorKind::ScaledSign,
            CompressorKind::NoisySign { noise_std: 0.1 },
            CompressorKind::Qsgd { levels: 1, norm: NormKind::L2 },
            CompressorKind::Qsgd { levels: 1, norm: NormKind::Linf },
            CompressorKind::Qsgd { levels: 255, norm: NormKind::L2 },
            CompressorKind::TernGrad,
            CompressorKind::Sparsign { budget: 1.0 },
            CompressorKind::TopK { k: 4 },
            CompressorKind::RandK { k: 4 },
            CompressorKind::ThresholdV { v: 0.1 },
            CompressorKind::Stc { k: 4 },
            CompressorKind::WorkerEf(Box::new(CompressorKind::Sign)),
            CompressorKind::Identity,
        ];
        let g: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) / 64.0).collect();
        for kind in kinds {
            let mut c = kind.build(g.len());
            let mut rng = Pcg64::seed_from(1);
            let msg = c.compress(&g, &mut rng);
            assert_eq!(msg.dim(), g.len(), "{}", kind.label());
            assert!(msg.bits() >= 0.0);
            assert!(!kind.label().is_empty());
        }
    }

    #[test]
    fn identity_roundtrips_exactly() {
        let g = vec![1.5, -2.25, 0.0, 3.0];
        let mut c = IdentityCompressor;
        let mut rng = Pcg64::seed_from(2);
        let msg = c.compress(&g, &mut rng);
        assert_eq!(msg.to_dense(), g);
        assert_eq!(msg.bits(), 128.0);
        assert_eq!(msg.nnz(), 3);
    }

    #[test]
    fn add_into_accumulates() {
        let msg = CompressedGrad::ternary_from_codes(&[1, -1, 0, 1], 2.0, 0.0);
        let mut acc = vec![1.0; 4];
        msg.add_into(&mut acc);
        assert_eq!(acc, vec![3.0, -1.0, 1.0, 3.0]);
        assert_eq!(msg.nnz(), 3);
    }

    #[test]
    fn packed_roundtrip_and_accessors() {
        // 130 coords crosses two word boundaries (64, 128).
        let mut codes = vec![0i8; 130];
        codes[0] = 1;
        codes[1] = -1;
        codes[63] = -1;
        codes[64] = 1;
        codes[127] = 1;
        codes[129] = -1;
        let pack = PackedTernary::from_codes(&codes, 0.5);
        assert_eq!(pack.dim(), 130);
        assert_eq!(pack.nnz(), 6);
        assert_eq!(pack.scale(), 0.5);
        assert_eq!(pack.to_codes(), codes);
        for (i, &c) in codes.iter().enumerate() {
            assert_eq!(pack.get(i), c, "coord {i}");
        }
        let mut collected = Vec::new();
        pack.for_each_nonzero(|i, s| collected.push((i, s)));
        assert_eq!(
            collected,
            vec![(0, 1), (1, -1), (63, -1), (64, 1), (127, 1), (129, -1)]
        );
        let mut acc = vec![0.0f32; 130];
        pack.add_into(&mut acc);
        for (i, &c) in codes.iter().enumerate() {
            assert_eq!(acc[i], 0.5 * c as f32, "coord {i}");
        }
    }

    #[test]
    fn packed_set_maintains_nnz() {
        let mut pack = PackedTernary::zeros(70, 1.0);
        pack.set(3, 1);
        pack.set(65, -1);
        assert_eq!(pack.nnz(), 2);
        pack.set(3, -1); // overwrite keeps count
        assert_eq!(pack.nnz(), 2);
        assert_eq!(pack.get(3), -1);
        pack.set(3, 0); // clear decrements
        assert_eq!(pack.nnz(), 1);
        assert_eq!(pack.get(3), 0);
        assert_eq!(pack.get(65), -1);
    }

    #[test]
    fn packed_dense_signs_matches_convention() {
        let g = vec![0.5, -0.5, 0.0, -0.0, -3.0];
        let pack = PackedTernary::dense_signs(&g, 1.0);
        assert_eq!(pack.to_codes(), vec![1, -1, 1, 1, -1]);
        assert_eq!(pack.nnz(), 5);
    }

    #[test]
    fn packed_empty_dim() {
        let pack = PackedTernary::zeros(0, 1.0);
        assert_eq!(pack.dim(), 0);
        assert_eq!(pack.to_codes(), Vec::<i8>::new());
        let pack2 = PackedTernary::dense_signs(&[], 1.0);
        assert_eq!(pack2.nnz(), 0);
    }

    #[test]
    fn packed_reset_reuses_storage() {
        let mut pack = PackedTernary::from_codes(&[1, -1, 0, 1], 2.0);
        pack.reset(4, 1.0);
        assert_eq!(pack.nnz(), 0);
        assert_eq!(pack.to_codes(), vec![0, 0, 0, 0]);
        assert_eq!(pack.scale(), 1.0);
        // Shrinking and re-growing across word boundaries stays clean.
        pack.reset(130, 0.5);
        assert_eq!(pack.dim(), 130);
        assert!(pack.to_codes().iter().all(|&c| c == 0));
        pack.set(129, -1);
        pack.reset(3, 1.0);
        assert_eq!(pack.to_codes(), vec![0, 0, 0]);
    }

    #[test]
    fn load_words_roundtrips_and_validates() {
        // Round-trip: planes out of one message rebuild an equal message.
        let codes: Vec<i8> = (0..130).map(|i| [(0i8), 1, -1, 0, 1][i % 5]).collect();
        let src = PackedTernary::from_codes(&codes, 0.75);
        let mut dst = PackedTernary::zeros(0, 1.0);
        dst.load_words(
            src.dim(),
            src.scale(),
            src.mask_words().iter().copied().zip(src.sign_words().iter().copied()),
        )
        .unwrap();
        assert_eq!(src, dst);
        assert_eq!(dst.nnz(), src.nnz());

        // Word count mismatch.
        let words = [(0u64, 0u64)];
        assert!(dst.load_words(130, 1.0, words.iter().copied()).is_err());
        // Mask bit beyond dim (dim = 3, bit 5 set).
        let words = [(1u64 << 5, 0u64)];
        assert!(dst.load_words(3, 1.0, words.iter().copied()).is_err());
        // Sign outside support.
        let words = [(0b01u64, 0b10u64)];
        assert!(dst.load_words(3, 1.0, words.iter().copied()).is_err());
        // Non-finite scale.
        let words = [(0b01u64, 0b01u64)];
        assert!(dst.load_words(3, f32::NAN, words.iter().copied()).is_err());
        // A failed load leaves the scratch in a consistent empty state.
        assert_eq!(dst.nnz(), 0);
        // nnz is recomputed, not trusted: a valid load reports popcount.
        let words = [(0b101u64, 0b100u64)];
        dst.load_words(3, 2.0, words.iter().copied()).unwrap();
        assert_eq!(dst.to_codes(), vec![1, 0, -1]);
        assert_eq!(dst.nnz(), 2);
        assert_eq!(dst.scale(), 2.0);
    }

    #[test]
    fn writer_matches_builder() {
        let codes: Vec<i8> = (0..200).map(|i| [(1i8), -1, 0, 0, 1][i % 5]).collect();
        let mut built = PackedBuilder::new(codes.len());
        let mut reused = PackedTernary::zeros(0, 1.0);
        let mut writer = reused.start(codes.len());
        for &c in &codes {
            built.push(c);
            writer.push(c);
        }
        assert_eq!(writer.nnz(), built.nnz());
        writer.finish(0.25);
        let built = built.finish(0.25);
        assert_eq!(built, reused);
    }

    #[test]
    fn streaming_kinds_emit_into_scratch_identically() {
        // Every kind the streaming predicate admits must (a) implement
        // compress_ternary_into and (b) produce the same message and bit
        // cost as compress from the same RNG state, at scale 1.0.
        let kinds = [
            CompressorKind::Sign,
            CompressorKind::NoisySign { noise_std: 0.05 },
            CompressorKind::Sparsign { budget: 0.7 },
            CompressorKind::SparsignAuto { target_density: 0.2 },
            CompressorKind::StoSign { b: 2.0 },
            CompressorKind::Ssdm { beta: 0.5 },
        ];
        let g: Vec<f32> = (0..150).map(|i| ((i % 13) as f32 - 6.0) / 8.0).collect();
        let mut scratch = PackedTernary::zeros(0, 1.0);
        for kind in kinds {
            assert!(kind.streams_unit_ternary(), "{}", kind.label());
            let mut c1 = kind.build(g.len());
            let mut c2 = kind.build(g.len());
            for seed in [1u64, 2] {
                let msg = c1.compress(&g, &mut Pcg64::seed_from(seed));
                let bits = c2
                    .compress_ternary_into(&g, &mut Pcg64::seed_from(seed), &mut scratch)
                    .unwrap_or_else(|| panic!("{} must stream", kind.label()));
                let CompressedGrad::Ternary { pack, bits: msg_bits } = &msg else {
                    panic!("{} emitted a dense message", kind.label());
                };
                assert_eq!(pack, &scratch, "{}", kind.label());
                assert_eq!(*msg_bits, bits, "{}", kind.label());
                assert_eq!(scratch.scale(), 1.0, "{}", kind.label());
            }
        }
        // And kinds outside the predicate must decline.
        let mut scaled = CompressorKind::ScaledSign.build(g.len());
        assert!(!CompressorKind::ScaledSign.streams_unit_ternary());
        assert!(scaled
            .compress_ternary_into(&g, &mut Pcg64::seed_from(3), &mut scratch)
            .is_none());
    }

    #[test]
    fn only_ef_requires_state() {
        let g_dim = 8;
        let stateless = [
            CompressorKind::Sign,
            CompressorKind::Sparsign { budget: 1.0 },
            CompressorKind::TernGrad,
            CompressorKind::Qsgd { levels: 1, norm: NormKind::L2 },
        ];
        for k in stateless {
            assert!(!k.build(g_dim).requires_worker_state(), "{}", k.label());
        }
        let ef = CompressorKind::WorkerEf(Box::new(CompressorKind::Sign)).build(g_dim);
        assert!(ef.requires_worker_state());
    }
}
