//! Sign-based baselines: signSGD, scaled signSGD, noisy signSGD.

use super::{CompressedGrad, Compressor, PackedTernary};
use crate::coding::cost::CostModel;
use crate::util::l1_norm_f64;
use crate::util::rng::Pcg64;

/// signSGD (Bernstein et al. 2018): transmit `sign(g)` — one bit per
/// coordinate. Uses the `sign(0)=+1` convention so the message is always
/// exactly `d` bits (a dense bitmap, no positions needed). The packed
/// representation IS that bitmap: one output word per 64 gradients.
#[derive(Clone, Copy, Debug)]
pub struct SignCompressor;

impl Compressor for SignCompressor {
    fn compress(&mut self, g: &[f32], _rng: &mut Pcg64) -> CompressedGrad {
        let pack = PackedTernary::dense_signs(g, 1.0);
        CompressedGrad::ternary(pack, g.len() as f64)
    }

    fn compress_ternary_into(
        &mut self,
        g: &[f32],
        _rng: &mut Pcg64,
        out: &mut PackedTernary,
    ) -> Option<f64> {
        out.fill_dense_signs(g, 1.0);
        Some(g.len() as f64)
    }

    fn name(&self) -> String {
        "sign".into()
    }

    fn cost_model(&self) -> CostModel {
        CostModel::Dense { bits_per_coord: 1.0, overhead_bits: 0.0 }
    }
}

/// Scaled signSGD (Karimireddy et al. 2019): transmit
/// `(‖g‖₁/d) · sign(g)` — the α-approximate compressor the paper also uses
/// server-side in Algorithm 2. One bit per coordinate + one f32 scale.
#[derive(Clone, Copy, Debug)]
pub struct ScaledSignCompressor;

/// Compute the scaled-sign transform into a ternary message (shared with
/// the server-side aggregation rule in [`crate::coordinator`], which uses
/// the same f64 ℓ1 accumulation — an f32 running sum drifts for large
/// `d`, see `util::l1_norm_f64`).
pub fn scaled_sign_message(g: &[f32]) -> CompressedGrad {
    let d = g.len().max(1);
    let scale = (l1_norm_f64(g) / d as f64) as f32;
    let pack = PackedTernary::dense_signs(g, scale);
    CompressedGrad::ternary(pack, g.len() as f64 + 32.0)
}

impl Compressor for ScaledSignCompressor {
    fn compress(&mut self, g: &[f32], _rng: &mut Pcg64) -> CompressedGrad {
        scaled_sign_message(g)
    }

    fn name(&self) -> String {
        "scaled-sign".into()
    }

    fn cost_model(&self) -> CostModel {
        CostModel::Dense { bits_per_coord: 1.0, overhead_bits: 32.0 }
    }
}

/// Noisy signSGD (Chen et al. 2020a): `sign(g + n)`, `n ~ N(0, σ²)` —
/// the unimodal-noise fix for the non-convergence of plain sign.
#[derive(Clone, Copy, Debug)]
pub struct NoisySignCompressor {
    /// Standard deviation of the added Gaussian noise (the paper tunes
    /// σ ∈ {0.001, 0.01, 0.1, 1.0}).
    pub noise_std: f32,
}

impl NoisySignCompressor {
    /// Streaming emission into a reusable packed message (shared by
    /// `compress` and the engine's zero-allocation path, so both consume
    /// the same RNG stream); returns the message bit cost.
    fn emit_into(&self, g: &[f32], rng: &mut Pcg64, out: &mut PackedTernary) -> f64 {
        let std = self.noise_std;
        // §Perf: Box–Muller yields two variates per ln/sqrt; consume both.
        let mut pk = out.start(g.len());
        let pairs = g.len() / 2;
        for idx in 0..pairs {
            let (n0, n1) = rng.normal_pair();
            let i = 2 * idx;
            pk.push(if g[i] + std * (n0 as f32) < 0.0 { -1 } else { 1 });
            pk.push(if g[i + 1] + std * (n1 as f32) < 0.0 { -1 } else { 1 });
        }
        if g.len() % 2 == 1 {
            let i = g.len() - 1;
            pk.push(if g[i] + rng.normal_f32(0.0, std) < 0.0 { -1 } else { 1 });
        }
        pk.finish(1.0);
        g.len() as f64
    }
}

impl Compressor for NoisySignCompressor {
    fn compress(&mut self, g: &[f32], rng: &mut Pcg64) -> CompressedGrad {
        let mut pack = PackedTernary::zeros(0, 1.0);
        let bits = self.emit_into(g, rng, &mut pack);
        CompressedGrad::ternary(pack, bits)
    }

    fn compress_ternary_into(
        &mut self,
        g: &[f32],
        rng: &mut Pcg64,
        out: &mut PackedTernary,
    ) -> Option<f64> {
        Some(self.emit_into(g, rng, out))
    }

    fn name(&self) -> String {
        format!("noisy-sign(std={})", self.noise_std)
    }

    fn cost_model(&self) -> CostModel {
        CostModel::Dense { bits_per_coord: 1.0, overhead_bits: 0.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sign_is_dense_one_bit() {
        let g = vec![0.5, -0.5, 0.0, -0.0];
        let mut c = SignCompressor;
        let mut rng = Pcg64::seed_from(1);
        let msg = c.compress(&g, &mut rng);
        match &msg {
            CompressedGrad::Ternary { pack, bits } => {
                assert_eq!(pack.to_codes(), vec![1, -1, 1, 1]);
                assert_eq!(pack.scale(), 1.0);
                assert_eq!(*bits, 4.0);
            }
            _ => panic!("wrong payload"),
        }
    }

    #[test]
    fn scaled_sign_scale_is_l1_over_d() {
        let g = vec![1.0, -3.0, 0.0, 4.0];
        let mut c = ScaledSignCompressor;
        let mut rng = Pcg64::seed_from(2);
        match c.compress(&g, &mut rng) {
            CompressedGrad::Ternary { pack, bits } => {
                assert_eq!(pack.scale(), 2.0);
                assert_eq!(bits, 36.0);
                assert_eq!(pack.to_codes(), vec![1, -1, 1, 1]);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn scaled_sign_is_alpha_approximate() {
        // ‖C(x) - x‖² ≤ (1-α)‖x‖² with α = ‖x‖₁²/(d‖x‖₂²) for scaled sign.
        let mut rng = Pcg64::seed_from(3);
        for _ in 0..50 {
            let mut g = vec![0.0; 64];
            rng.fill_normal(&mut g, 0.0, 1.0);
            let c = scaled_sign_message(&g).to_dense();
            let err: f32 = c.iter().zip(&g).map(|(a, b)| (a - b) * (a - b)).sum();
            let x2: f32 = g.iter().map(|x| x * x).sum();
            let l1: f32 = g.iter().map(|x| x.abs()).sum();
            let alpha = l1 * l1 / (64.0 * x2);
            assert!(err <= (1.0 - alpha) * x2 + 1e-3, "err {err} bound {}", (1.0 - alpha) * x2);
        }
    }

    #[test]
    fn noisy_sign_flips_small_coords_sometimes() {
        let g = vec![0.01f32; 1000];
        let mut c = NoisySignCompressor { noise_std: 1.0 };
        let mut rng = Pcg64::seed_from(4);
        let msg = c.compress(&g, &mut rng);
        let neg = match &msg {
            CompressedGrad::Ternary { pack, .. } => {
                pack.to_codes().iter().filter(|&&x| x == -1).count()
            }
            _ => panic!(),
        };
        // sign flips with prob Φ(-0.01) ≈ 0.496.
        assert!(neg > 400 && neg < 600, "neg={neg}");
    }

    #[test]
    fn noisy_sign_zero_noise_equals_sign() {
        let g = vec![0.5, -0.25, 3.0];
        let mut a = NoisySignCompressor { noise_std: 0.0 };
        let mut b = SignCompressor;
        let mut r1 = Pcg64::seed_from(5);
        let mut r2 = Pcg64::seed_from(5);
        assert_eq!(a.compress(&g, &mut r1).to_dense(), b.compress(&g, &mut r2).to_dense());
    }

    #[test]
    fn empty_gradient_ok() {
        let mut c = ScaledSignCompressor;
        let mut rng = Pcg64::seed_from(6);
        let msg = c.compress(&[], &mut rng);
        assert_eq!(msg.dim(), 0);
    }
}
