//! QSGD (Alistarh et al. 2017) and its 1-bit variants, exactly as the
//! paper's Appendix B describes them:
//!
//! `Q_s(g, s) = ‖g‖ · sign(g) · ξ(g, s)` where `ξ` stochastically rounds
//! `|g_i|/‖g‖ · s` to a neighbouring integer level `l ∈ {0..s}`.
//!
//! * `s = 1, ‖·‖ = ℓ2`  → "1-bit L2 norm QSGD" (ternary message).
//! * `s = 1, ‖·‖ = ℓ∞` → "1-bit L∞ norm QSGD" (ternary, denser).
//! * `s = 255`          → the 8-bit QSGD used inside FedCom.

use super::{CompressedGrad, Compressor, PackedBuilder, PackedTernary};
use crate::coding::cost::CostModel;
use crate::util::rng::{bernoulli_threshold, Pcg64, U32Stream};
use crate::util::{l2_norm, linf_norm};

/// Which norm scales the quantization grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NormKind {
    L2,
    Linf,
}

/// Stochastic `s`-level quantizer.
#[derive(Clone, Copy, Debug)]
pub struct QsgdCompressor {
    /// Number of quantization levels `s ≥ 1`.
    pub levels: u32,
    /// Norm used for the scale.
    pub norm: NormKind,
}

impl QsgdCompressor {
    fn norm_of(&self, g: &[f32]) -> f32 {
        match self.norm {
            NormKind::L2 => l2_norm(g),
            NormKind::Linf => linf_norm(g),
        }
    }
}

impl Compressor for QsgdCompressor {
    fn compress(&mut self, g: &[f32], rng: &mut Pcg64) -> CompressedGrad {
        assert!(self.levels >= 1, "QSGD needs at least one level");
        let s = self.levels;
        let nrm = self.norm_of(g);
        if nrm == 0.0 || g.is_empty() {
            // Zero gradient: transmit the (zero) norm only.
            return if s == 1 {
                CompressedGrad::ternary(PackedTernary::zeros(g.len(), 0.0), 32.0)
            } else {
                CompressedGrad::dense_with_nnz(vec![0.0; g.len()], 0, 32.0)
            };
        }
        let sf = s as f32;
        if s == 1 {
            // Ternary fast path: keep-probability |g_i|/‖g‖ (level 1 vs 0).
            let mut pk = PackedBuilder::new(g.len());
            let mut u = U32Stream::new(rng);
            for &gi in g.iter() {
                let thr = bernoulli_threshold(gi.abs() / nrm);
                pk.push(if u.bernoulli(thr) {
                    if gi > 0.0 {
                        1
                    } else {
                        -1
                    }
                } else {
                    0
                });
            }
            let pack = pk.finish(nrm);
            let bits = CostModel::Qsgd { levels: 1 }.bits(g.len(), pack.nnz());
            return CompressedGrad::ternary(pack, bits);
        }
        // General s-level path: value = ‖g‖·sign·(l or l+1)/s.
        let mut v = vec![0.0f32; g.len()];
        let mut nnz = 0usize;
        for (vi, &gi) in v.iter_mut().zip(g.iter()) {
            let a = (gi.abs() / nrm * sf).min(sf);
            let l = a.floor();
            let frac = a - l;
            let level = if rng.f32() < frac { l + 1.0 } else { l };
            if level > 0.0 {
                *vi = nrm * gi.signum() * level / sf;
                nnz += 1;
            }
        }
        let bits = CostModel::Qsgd { levels: s }.bits(g.len(), nnz);
        CompressedGrad::dense_with_nnz(v, nnz, bits)
    }

    fn name(&self) -> String {
        format!(
            "qsgd(s={}, {})",
            self.levels,
            match self.norm {
                NormKind::L2 => "l2",
                NormKind::Linf => "linf",
            }
        )
    }

    fn cost_model(&self) -> CostModel {
        CostModel::Qsgd { levels: self.levels }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_bit_l2_is_unbiased() {
        // E[Q(g)] = g for QSGD (unbiased by construction).
        let g = vec![0.6f32, -0.8]; // ‖g‖₂ = 1
        let mut c = QsgdCompressor { levels: 1, norm: NormKind::L2 };
        let mut rng = Pcg64::seed_from(1);
        let trials = 50_000;
        let mut sums = [0.0f64; 2];
        for _ in 0..trials {
            let d = c.compress(&g, &mut rng).to_dense();
            sums[0] += d[0] as f64;
            sums[1] += d[1] as f64;
        }
        assert!((sums[0] / trials as f64 - 0.6).abs() < 0.01);
        assert!((sums[1] / trials as f64 + 0.8).abs() < 0.01);
    }

    #[test]
    fn linf_variant_is_denser_than_l2() {
        let mut rng_data = Pcg64::seed_from(2);
        let mut g = vec![0.0; 4096];
        rng_data.fill_normal(&mut g, 0.0, 1.0);
        let mut c2 = QsgdCompressor { levels: 1, norm: NormKind::L2 };
        let mut ci = QsgdCompressor { levels: 1, norm: NormKind::Linf };
        let mut r1 = Pcg64::seed_from(3);
        let mut r2 = Pcg64::seed_from(3);
        // L∞ norm is much smaller than L2 on a long vector, so the
        // keep-probabilities |g|/‖g‖ are higher ⇒ denser message.
        let n2 = c2.compress(&g, &mut r1).nnz();
        let ni = ci.compress(&g, &mut r2).nnz();
        assert!(ni > 4 * n2, "linf nnz {ni} vs l2 nnz {n2}");
    }

    #[test]
    fn multi_level_reconstruction_error_shrinks_with_s() {
        let mut rng_data = Pcg64::seed_from(4);
        let mut g = vec![0.0; 512];
        rng_data.fill_normal(&mut g, 0.0, 1.0);
        let mut err_prev = f64::INFINITY;
        for &s in &[1u32, 4, 16, 255] {
            let mut c = QsgdCompressor { levels: s, norm: NormKind::L2 };
            let mut rng = Pcg64::seed_from(5);
            let mut err = 0.0f64;
            let trials = 32;
            for _ in 0..trials {
                let d = c.compress(&g, &mut rng).to_dense();
                err += d
                    .iter()
                    .zip(&g)
                    .map(|(a, b)| ((a - b) * (a - b)) as f64)
                    .sum::<f64>();
            }
            err /= trials as f64;
            assert!(err < err_prev * 1.05, "s={s}: err {err} prev {err_prev}");
            err_prev = err;
        }
    }

    #[test]
    fn zero_gradient_costs_norm_only() {
        let mut c = QsgdCompressor { levels: 1, norm: NormKind::L2 };
        let mut rng = Pcg64::seed_from(6);
        let msg = c.compress(&[0.0; 32], &mut rng);
        assert_eq!(msg.bits(), 32.0);
        assert_eq!(msg.nnz(), 0);
    }

    #[test]
    fn levels_bounded_by_s() {
        let g = vec![10.0f32, -0.1, 0.5, 0.0];
        let mut c = QsgdCompressor { levels: 4, norm: NormKind::Linf };
        let mut rng = Pcg64::seed_from(7);
        for _ in 0..100 {
            let d = c.compress(&g, &mut rng).to_dense();
            let nrm = 10.0;
            for (i, &v) in d.iter().enumerate() {
                let lvl = (v.abs() / nrm * 4.0).round();
                assert!(lvl <= 4.0, "coord {i} level {lvl}");
            }
        }
    }
}
