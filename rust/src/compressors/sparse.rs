//! Magnitude- and position-based sparsifiers from the related-work
//! baselines: Top-k, Random-k, Threshold-v (full-precision values) and STC
//! (Sattler et al. 2019a: Top-k + mean-magnitude binarization).

use super::{ternary_bits, CompressedGrad, Compressor, PackedTernary};
use crate::coding::cost::CostModel;
use crate::util::rng::Pcg64;

/// Indices of the `k` largest-|·| coordinates (ties broken by index).
fn topk_indices(g: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(g.len());
    let mut idx: Vec<usize> = (0..g.len()).collect();
    // Partial selection: full sort is fine at substrate scale, but use
    // select_nth for O(d) average.
    if k < g.len() {
        idx.select_nth_unstable_by(k, |&a, &b| {
            g[b].abs().partial_cmp(&g[a].abs()).unwrap_or(std::cmp::Ordering::Equal)
        });
        idx.truncate(k);
    }
    idx.sort_unstable();
    idx
}

/// Top-k sparsification (Alistarh et al. 2018): keep the k
/// largest-magnitude coordinates at full precision.
#[derive(Clone, Copy, Debug)]
pub struct TopKCompressor {
    pub k: usize,
}

impl Compressor for TopKCompressor {
    fn compress(&mut self, g: &[f32], _rng: &mut Pcg64) -> CompressedGrad {
        let idx = topk_indices(g, self.k);
        let mut v = vec![0.0f32; g.len()];
        let mut nnz = 0;
        for &i in &idx {
            if g[i] != 0.0 {
                v[i] = g[i];
                nnz += 1;
            }
        }
        let bits = CostModel::SparseFloat.bits(g.len(), nnz);
        CompressedGrad::dense_with_nnz(v, nnz, bits)
    }

    fn name(&self) -> String {
        format!("top{}", self.k)
    }

    fn cost_model(&self) -> CostModel {
        CostModel::SparseFloat
    }
}

/// Random-k sparsification (Stich et al. 2018): keep k uniformly random
/// coordinates, rescaled by d/k for unbiasedness.
#[derive(Clone, Copy, Debug)]
pub struct RandKCompressor {
    pub k: usize,
}

impl Compressor for RandKCompressor {
    fn compress(&mut self, g: &[f32], rng: &mut Pcg64) -> CompressedGrad {
        let k = self.k.min(g.len());
        let idx = rng.sample_indices(g.len(), k);
        let scale = if k == 0 { 0.0 } else { g.len() as f32 / k as f32 };
        let mut v = vec![0.0f32; g.len()];
        let mut nnz = 0;
        for &i in &idx {
            if g[i] != 0.0 {
                v[i] = g[i] * scale;
                nnz += 1;
            }
        }
        let bits = CostModel::SparseFloat.bits(g.len(), nnz);
        CompressedGrad::dense_with_nnz(v, nnz, bits)
    }

    fn name(&self) -> String {
        format!("rand{}", self.k)
    }

    fn cost_model(&self) -> CostModel {
        CostModel::SparseFloat
    }
}

/// Threshold-v sparsification (Lin et al. 2018; Sahu et al. 2021): keep
/// coordinates with |g_i| > v at full precision.
#[derive(Clone, Copy, Debug)]
pub struct ThresholdVCompressor {
    pub v: f32,
}

impl Compressor for ThresholdVCompressor {
    fn compress(&mut self, g: &[f32], _rng: &mut Pcg64) -> CompressedGrad {
        let mut v = vec![0.0f32; g.len()];
        let mut nnz = 0;
        for (vi, &gi) in v.iter_mut().zip(g.iter()) {
            if gi.abs() > self.v {
                *vi = gi;
                nnz += 1;
            }
        }
        let bits = CostModel::SparseFloat.bits(g.len(), nnz);
        CompressedGrad::dense_with_nnz(v, nnz, bits)
    }

    fn name(&self) -> String {
        format!("threshold{}", self.v)
    }

    fn cost_model(&self) -> CostModel {
        CostModel::SparseFloat
    }
}

/// Sparse ternary compression (Sattler et al. 2019a): Top-k followed by
/// binarization to `μ · sign`, μ = mean |g_i| over the kept set — ternary
/// message + one f32 scale.
#[derive(Clone, Copy, Debug)]
pub struct StcCompressor {
    pub k: usize,
}

impl Compressor for StcCompressor {
    fn compress(&mut self, g: &[f32], _rng: &mut Pcg64) -> CompressedGrad {
        let idx = topk_indices(g, self.k);
        let kept: Vec<f32> = idx.iter().map(|&i| g[i]).filter(|x| *x != 0.0).collect();
        if kept.is_empty() {
            return CompressedGrad::ternary(PackedTernary::zeros(g.len(), 0.0), 32.0);
        }
        let mu = kept.iter().map(|x| x.abs()).sum::<f32>() / kept.len() as f32;
        let mut pack = PackedTernary::zeros(g.len(), mu);
        for &i in &idx {
            if g[i] != 0.0 {
                pack.set(i, if g[i] > 0.0 { 1 } else { -1 });
            }
        }
        let bits = ternary_bits(g.len(), pack.nnz(), true);
        CompressedGrad::ternary(pack, bits)
    }

    fn name(&self) -> String {
        format!("stc(k={})", self.k)
    }

    fn cost_model(&self) -> CostModel {
        CostModel::SparseTernary
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topk_keeps_largest() {
        let g = vec![0.1, -5.0, 0.3, 2.0, -0.2];
        let mut c = TopKCompressor { k: 2 };
        let mut rng = Pcg64::seed_from(1);
        let d = c.compress(&g, &mut rng).to_dense();
        assert_eq!(d, vec![0.0, -5.0, 0.0, 2.0, 0.0]);
    }

    #[test]
    fn topk_k_larger_than_d() {
        let g = vec![1.0, 2.0];
        let mut c = TopKCompressor { k: 10 };
        let mut rng = Pcg64::seed_from(2);
        assert_eq!(c.compress(&g, &mut rng).to_dense(), g);
    }

    #[test]
    fn randk_is_unbiased() {
        let g = vec![1.0f32, 2.0, 3.0, 4.0];
        let mut c = RandKCompressor { k: 2 };
        let mut rng = Pcg64::seed_from(3);
        let trials = 40_000;
        let mut sums = vec![0.0f64; 4];
        for _ in 0..trials {
            for (s, v) in sums.iter_mut().zip(c.compress(&g, &mut rng).to_dense()) {
                *s += v as f64;
            }
        }
        for (i, &s) in sums.iter().enumerate() {
            let mean = s / trials as f64;
            assert!((mean - g[i] as f64).abs() < 0.06, "coord {i}: {mean}");
        }
    }

    #[test]
    fn randk_zero_k() {
        let mut c = RandKCompressor { k: 0 };
        let mut rng = Pcg64::seed_from(4);
        let msg = c.compress(&[1.0, 2.0], &mut rng);
        assert_eq!(msg.nnz(), 0);
        assert_eq!(msg.bits(), 0.0);
    }

    #[test]
    fn threshold_exact_boundary_excluded() {
        let g = vec![0.1, 0.100001, -0.3];
        let mut c = ThresholdVCompressor { v: 0.1 };
        let mut rng = Pcg64::seed_from(5);
        let d = c.compress(&g, &mut rng).to_dense();
        assert_eq!(d[0], 0.0); // strictly greater-than
        assert!(d[1] != 0.0 && d[2] != 0.0);
    }

    #[test]
    fn stc_binarizes_to_mean_magnitude() {
        let g = vec![4.0, -2.0, 0.1, 0.0];
        let mut c = StcCompressor { k: 2 };
        let mut rng = Pcg64::seed_from(6);
        match c.compress(&g, &mut rng) {
            CompressedGrad::Ternary { pack, .. } => {
                assert_eq!(pack.to_codes(), vec![1, -1, 0, 0]);
                assert_eq!(pack.scale(), 3.0); // (4+2)/2
            }
            _ => panic!(),
        }
    }

    #[test]
    fn stc_all_zero_gradient() {
        let mut c = StcCompressor { k: 3 };
        let mut rng = Pcg64::seed_from(7);
        let msg = c.compress(&[0.0; 5], &mut rng);
        assert_eq!(msg.nnz(), 0);
    }

    #[test]
    fn cost_ordering_topk_vs_stc() {
        // Same support size: STC (1 sign bit/coord) must be cheaper than
        // Top-k (32 value bits/coord).
        let g: Vec<f32> = (0..1024).map(|i| ((i % 61) as f32 - 30.0) / 30.0).collect();
        let mut tk = TopKCompressor { k: 64 };
        let mut st = StcCompressor { k: 64 };
        let mut r = Pcg64::seed_from(8);
        assert!(st.compress(&g, &mut r).bits() < tk.compress(&g, &mut r).bits());
    }
}
