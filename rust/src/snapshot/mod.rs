//! Coordinator snapshot/restore (DESIGN.md §12): the elastic-federation
//! subsystem that lets a training run outlive its coordinator process.
//!
//! A [`CoordinatorSnapshot`] captures the full server-side round state at
//! a round boundary — model parameters, the server-side EF residual
//! (Algorithm 2, eq. 8), the selection RNG stream, the per-round report
//! and [`CommLedger`] history, and the protocol phase — in one
//! CRC-guarded file. The determinism contract (DESIGN.md §2/§10) makes
//! this *sufficient* for bit-identical resume: worker RNG streams are
//! derived per `(seed, round, worker)` and never persist, stateless
//! compressors carry nothing across rounds, and the only stateful
//! server-side objects are exactly the fields serialized here. A resumed
//! run therefore replays the remaining rounds onto the restored state
//! and produces a `RunHistory` bit-for-bit equal to an uninterrupted
//! run (`tests/snapshot_resume.rs`; the `resume-equivalence` CI job
//! pins the cross-process version over TCP and UDS).
//!
//! ## File grammar (version 3; versions 1 and 2 still load)
//!
//! ```text
//! snapshot := magic:u32be("SGSP")  version:u8  kind:u8(=1)
//!             len:varint  body[len]  crc:u32le
//! body     := fingerprint:u64le
//!             dim:varint  workers:varint  rounds_total:varint
//!             next_round:varint
//!             phase_tag:u8  phase_round:varint
//!             selection
//!             params: dim × f32le
//!             residual_flag:u8  [ residual: dim × f32le ]
//!             nreports:varint  report[nreports]
//!             nledger:varint   ledgerrec[nledger]
//!             rejects: 6 × varint            (v2 onward)
//! selection:= v1:  select_rng: 4 × u64le     (legacy raw state)
//!             v2:  sel_tag:u8
//!                  0 → select_rng: 4 × u64le (legacy raw state)
//!                  1 → commitment: 4 × u64le  sel_round:varint
//! report   := round:varint  lr:f64le  train_loss:f64le
//!             eval_flag:u8 [ eval_loss:f64le  eval_acc:f64le ]
//!             uplink_bits:f64le  downlink_bits:f64le
//!             cum_uplink_bits:f64le
//! ledgerrec:= uplink_bits:f64le  downlink_bits:f64le  senders:varint
//!             uplink_nnz:varint  uplink_wire_bytes:varint
//!             downlink_wire_bytes:varint  stragglers:varint
//!             [ shard_uplink_wire_bytes:varint            (v3 only)
//!               shard_downlink_wire_bytes:varint ]
//! ```
//!
//! Version 2 (the hardened-selection bump, DESIGN.md §13) adds the
//! selection-mode tag — committed-seed runs serialize a one-way
//! commitment plus a round counter and **never** raw RNG state — and the
//! cumulative typed-reject counters. Version 3 (the aggregation-tree
//! bump, DESIGN.md §14) appends the per-round shard-tier wire-byte
//! columns to each ledger record. Writers always emit v3; the loader
//! still accepts v1 (legacy raw selection, zero rejects) and v2 (zero
//! shard-tier bytes) files, so snapshots written by previous releases
//! resume cleanly.
//!
//! The framing deliberately reuses the `net/wire.rs` building blocks —
//! the [`crate::coding::bitio`] MSB-first header, LEB128 varints, and
//! the same CRC-32 — so one hardened codec vocabulary covers both byte
//! boundaries in the system.
//!
//! ## Hardening
//!
//! Loading mirrors `PackedTernary::load_words`: every field of a
//! snapshot file is untrusted. The declared body length is capped by
//! [`MAX_SNAPSHOT`] *before* any allocation (and [`CoordinatorSnapshot::load`]
//! checks the file's metadata length before reading it), every count is
//! bounded (`dim` by [`MAX_DIM`], rounds by [`MAX_ROUNDS`], report/ledger
//! counts by the declared round index), vectors grow only from bytes
//! actually present, cross-field consistency (phase ↔ round index,
//! report contiguity, report/ledger arity, RNG increment parity) is
//! revalidated, and every failure is a typed [`SnapshotError`] — no
//! panics, no attacker-length allocations
//! (`tests/property_suite.rs` fuzzes mutations and truncations).
//!
//! ## Atomicity
//!
//! [`CoordinatorSnapshot::save`] writes to `<path>.tmp`, fsyncs, then
//! renames over `<path>` (and fsyncs the parent directory on unix), so a
//! crash mid-write leaves either the previous snapshot or the new one —
//! never a torn file.
//!
//! ## Version policy
//!
//! One version byte, bumped on any incompatible layout change; loaders
//! reject mismatches with [`SnapshotError::BadVersion`] (no migration —
//! a snapshot is a short-lived crash artifact, not an archive format).
//! The `kind` byte namespaces future snapshot flavors; unknown kinds
//! fail loudly ([`SnapshotError::BadKind`]). The layout itself is pinned
//! by a golden test in `tests/property_suite.rs` that re-encodes the
//! grammar independently.

use std::path::{Path, PathBuf};

use crate::coding::bitio::{BitReader, BitWriter};
use crate::coordinator::{CommLedger, RoundComm, RoundReport, SelectionSnapshot, REJECT_KINDS};
use crate::net::wire::{crc32, push_varint, Cursor, WireError};

/// Snapshot file magic: `"SGSP"` read MSB-first.
pub const SNAP_MAGIC: u32 = 0x5347_5350;
/// Current snapshot-format version (what writers emit).
pub const SNAP_VERSION: u8 = 3;
/// The hardened-selection format (selection tag + reject counters, no
/// shard-tier wire bytes); still loads.
pub const SNAP_VERSION_V2: u8 = 2;
/// Oldest version the loader still accepts (legacy raw selection, no
/// reject counters).
pub const SNAP_VERSION_V1: u8 = 1;
/// Snapshot kind byte: the full-coordinator state (the only kind so far).
pub const KIND_COORDINATOR: u8 = 1;
/// Fixed header bytes before the length varint (magic + version + kind).
pub const HEADER_FIXED: usize = 6;
/// Trailing checksum bytes.
pub const CRC_LEN: usize = 4;
/// Hard body cap: decoders refuse to proceed past this, bounding memory
/// even against a hostile length prefix (and `load` refuses larger
/// files before reading them).
pub const MAX_SNAPSHOT: usize = 1 << 30;
/// Model-dimension cap (64M coordinates ≈ 256 MiB of f32 parameters).
pub const MAX_DIM: usize = 1 << 26;
/// Round-count cap.
pub const MAX_ROUNDS: usize = 1 << 24;
/// Worker-population cap.
pub const MAX_WORKERS: usize = 1 << 24;

/// Typed snapshot failure. Never panics, never over-allocates.
#[derive(Debug)]
pub enum SnapshotError {
    /// Filesystem-level failure (open/read/write/rename/fsync).
    Io(std::io::Error),
    /// Fewer bytes than the file (or field) requires.
    Truncated { need: usize, have: usize },
    /// First four bytes are not [`SNAP_MAGIC`].
    BadMagic { got: u32 },
    /// Version byte differs from [`SNAP_VERSION`].
    BadVersion { got: u8 },
    /// Unknown snapshot-kind byte.
    BadKind { got: u8 },
    /// Checksum mismatch (torn or corrupt file).
    BadCrc { want: u32, got: u32 },
    /// Declared body length exceeds the decoder's cap.
    Oversized { len: u64, max: usize },
    /// Structurally invalid body (bad varint, count mismatch, violated
    /// cross-field invariant, trailing garbage, …).
    Malformed(&'static str),
    /// A structurally valid snapshot that does not belong to this run
    /// (config fingerprint / dimension / population mismatch).
    Incompatible(String),
    /// The run configuration cannot be snapshotted (stateful worker
    /// compressors keep client-side state no coordinator file can carry).
    Unsupported(&'static str),
}

impl std::fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SnapshotError::Io(e) => write!(f, "snapshot io: {e}"),
            SnapshotError::Truncated { need, have } => {
                write!(f, "truncated snapshot: need {need} bytes, have {have}")
            }
            SnapshotError::BadMagic { got } => write!(f, "bad snapshot magic {got:#010x}"),
            SnapshotError::BadVersion { got } => {
                write!(
                    f,
                    "snapshot version {got} (this build speaks {SNAP_VERSION_V1}..={SNAP_VERSION})"
                )
            }
            SnapshotError::BadKind { got } => write!(f, "unknown snapshot kind {got}"),
            SnapshotError::BadCrc { want, got } => {
                write!(f, "snapshot crc mismatch: file says {want:#010x}, computed {got:#010x}")
            }
            SnapshotError::Oversized { len, max } => {
                write!(f, "snapshot length {len} exceeds cap {max}")
            }
            SnapshotError::Malformed(what) => write!(f, "malformed snapshot: {what}"),
            SnapshotError::Incompatible(what) => write!(f, "incompatible snapshot: {what}"),
            SnapshotError::Unsupported(what) => write!(f, "snapshot unsupported: {what}"),
        }
    }
}

impl std::error::Error for SnapshotError {}

impl From<std::io::Error> for SnapshotError {
    fn from(e: std::io::Error) -> Self {
        SnapshotError::Io(e)
    }
}

impl From<WireError> for SnapshotError {
    fn from(e: WireError) -> Self {
        match e {
            WireError::Truncated { need, have } => SnapshotError::Truncated { need, have },
            WireError::BadMagic { got } => SnapshotError::BadMagic { got },
            WireError::BadVersion { got } => SnapshotError::BadVersion { got },
            WireError::BadMsgType { got } => SnapshotError::BadKind { got },
            WireError::BadCrc { want, got } => SnapshotError::BadCrc { want, got },
            WireError::Oversized { len, max } => SnapshotError::Oversized { len, max },
            WireError::Malformed(what) => SnapshotError::Malformed(what),
        }
    }
}

/// Protocol phase at the snapshot boundary. Snapshots are only taken
/// between rounds, so the phase is either `Standby` (nothing ran yet) or
/// `Broadcast(t)` (round `t` fully applied, its `RoundTable` closed);
/// the loader rejects any other combination.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SnapPhase {
    /// No round completed; a resume starts from round 0.
    Standby,
    /// Round `t` completed and applied; a resume starts from `t + 1`.
    Broadcast(usize),
}

/// When the engine writes snapshots.
#[derive(Clone, Debug)]
pub struct SnapshotPolicy {
    /// Destination file (written atomically; see the module docs).
    pub path: PathBuf,
    /// Write after every `every` completed rounds; `0` means only on an
    /// explicit drain (the `net` coordinator's graceful-shutdown path).
    pub every: usize,
}

impl SnapshotPolicy {
    /// Snapshot every `every` completed rounds into `path`.
    ///
    /// ```
    /// use sparsignd::snapshot::SnapshotPolicy;
    ///
    /// let policy = SnapshotPolicy::every("target/run.snap", 3);
    /// assert!(policy.due(3, 10) && !policy.due(4, 10));
    /// // The final round never writes a periodic snapshot:
    /// assert!(!policy.due(10, 10));
    /// ```
    pub fn every(path: impl Into<PathBuf>, every: usize) -> Self {
        Self { path: path.into(), every }
    }

    /// Snapshot only when the coordinator drains.
    pub fn on_drain(path: impl Into<PathBuf>) -> Self {
        Self { path: path.into(), every: 0 }
    }

    /// True when a periodic snapshot is due after `done` of `total`
    /// rounds (the final round never writes one — the run is complete).
    pub fn due(&self, done: usize, total: usize) -> bool {
        self.every > 0 && done % self.every == 0 && done < total
    }
}

/// The full serialized coordinator state at a round boundary.
///
/// Fields are public for construction by the engine (and the benches);
/// everything is *re-validated* on [`CoordinatorSnapshot::decode`], so
/// in-memory construction is trusted but files never are.
#[derive(Clone, Debug, PartialEq)]
pub struct CoordinatorSnapshot {
    /// Run-configuration fingerprint (algorithm, schedule, rounds,
    /// participation, eval cadence, seed, dim, workers). A resume
    /// refuses a snapshot whose fingerprint differs from the run it is
    /// asked to continue.
    pub fingerprint: u64,
    /// Model dimension `d`.
    pub dim: usize,
    /// Worker population `M`.
    pub workers: usize,
    /// Total rounds the run was configured for.
    pub rounds_total: usize,
    /// Protocol phase at the boundary (checked against `next_round`).
    pub phase: SnapPhase,
    /// Serialized selection state. Legacy runs carry the raw `Pcg64`
    /// words ([`crate::util::rng::Pcg64::to_raw`]); hardened committed-
    /// seed runs carry only the root-key commitment plus the round
    /// counter — the raw generator state never touches the file
    /// (DESIGN.md §13).
    pub selection: SelectionSnapshot,
    /// Model parameters after the last completed round.
    pub params: Vec<f32>,
    /// Algorithm 2's server-side EF residual `ẽ`; `None` for algorithms
    /// without server state.
    pub residual: Option<Vec<f32>>,
    /// Per-round reports for every completed round, in round order.
    pub reports: Vec<RoundReport>,
    /// Communication ledger for every completed round.
    pub ledger: CommLedger,
}

impl CoordinatorSnapshot {
    /// Rounds already completed — the round index a resume starts from.
    pub fn next_round(&self) -> usize {
        self.reports.len()
    }

    /// Serialize to one self-contained byte buffer.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }

    /// Serialize, appending to `out`; returns the snapshot's byte length.
    pub fn encode_into(&self, out: &mut Vec<u8>) -> usize {
        assert_eq!(self.params.len(), self.dim, "snapshot params dim mismatch");
        if let Some(r) = &self.residual {
            assert_eq!(r.len(), self.dim, "snapshot residual dim mismatch");
        }
        assert_eq!(
            self.ledger.rounds(),
            self.reports.len(),
            "snapshot ledger/report arity mismatch"
        );
        let next = self.reports.len();
        let mut body = Vec::new();
        body.extend_from_slice(&self.fingerprint.to_le_bytes());
        push_varint(&mut body, self.dim as u64);
        push_varint(&mut body, self.workers as u64);
        push_varint(&mut body, self.rounds_total as u64);
        push_varint(&mut body, next as u64);
        match self.phase {
            SnapPhase::Standby => {
                body.push(0);
                push_varint(&mut body, 0);
            }
            SnapPhase::Broadcast(t) => {
                body.push(1);
                push_varint(&mut body, t as u64);
            }
        }
        match &self.selection {
            SelectionSnapshot::LegacyRaw(raw) => {
                body.push(0);
                for w in raw {
                    body.extend_from_slice(&w.to_le_bytes());
                }
            }
            SelectionSnapshot::Committed { commitment, round } => {
                body.push(1);
                for w in commitment {
                    body.extend_from_slice(&w.to_le_bytes());
                }
                push_varint(&mut body, *round);
            }
        }
        for &x in &self.params {
            body.extend_from_slice(&x.to_le_bytes());
        }
        match &self.residual {
            None => body.push(0),
            Some(r) => {
                body.push(1);
                for &x in r {
                    body.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        push_varint(&mut body, self.reports.len() as u64);
        for r in &self.reports {
            push_varint(&mut body, r.round as u64);
            body.extend_from_slice(&r.lr.to_le_bytes());
            body.extend_from_slice(&r.train_loss.to_le_bytes());
            match r.eval {
                None => body.push(0),
                Some((l, a)) => {
                    body.push(1);
                    body.extend_from_slice(&l.to_le_bytes());
                    body.extend_from_slice(&a.to_le_bytes());
                }
            }
            body.extend_from_slice(&r.uplink_bits.to_le_bytes());
            body.extend_from_slice(&r.downlink_bits.to_le_bytes());
            body.extend_from_slice(&r.cum_uplink_bits.to_le_bytes());
        }
        push_varint(&mut body, self.ledger.rounds() as u64);
        for rec in self.ledger.records() {
            body.extend_from_slice(&rec.uplink_bits.to_le_bytes());
            body.extend_from_slice(&rec.downlink_bits.to_le_bytes());
            push_varint(&mut body, rec.senders as u64);
            push_varint(&mut body, rec.uplink_nnz as u64);
            push_varint(&mut body, rec.uplink_wire_bytes);
            push_varint(&mut body, rec.downlink_wire_bytes);
            push_varint(&mut body, rec.stragglers as u64);
            push_varint(&mut body, rec.shard_uplink_wire_bytes);
            push_varint(&mut body, rec.shard_downlink_wire_bytes);
        }
        for &n in self.ledger.rejects_by_kind() {
            push_varint(&mut body, n);
        }
        assert!(body.len() <= MAX_SNAPSHOT, "snapshot body {} B exceeds cap", body.len());

        let start = out.len();
        let mut hdr = BitWriter::new();
        hdr.push_bits(SNAP_MAGIC as u64, 32);
        hdr.push_bits(SNAP_VERSION as u64, 8);
        hdr.push_bits(KIND_COORDINATOR as u64, 8);
        out.extend_from_slice(hdr.as_bytes());
        push_varint(out, body.len() as u64);
        out.extend_from_slice(&body);
        let crc = crc32(&out[start..]);
        out.extend_from_slice(&crc.to_le_bytes());
        out.len() - start
    }

    /// Parse and fully validate one snapshot from `bytes` (which must
    /// contain exactly one snapshot — trailing bytes are an error).
    pub fn decode(bytes: &[u8]) -> Result<Self, SnapshotError> {
        if bytes.len() < HEADER_FIXED {
            return Err(SnapshotError::Truncated { need: HEADER_FIXED, have: bytes.len() });
        }
        let mut hdr = BitReader::new(&bytes[..HEADER_FIXED]);
        let magic = hdr.read_bits(32).expect("fixed header") as u32;
        if magic != SNAP_MAGIC {
            return Err(SnapshotError::BadMagic { got: magic });
        }
        let version = hdr.read_bits(8).expect("fixed header") as u8;
        if !(SNAP_VERSION_V1..=SNAP_VERSION).contains(&version) {
            return Err(SnapshotError::BadVersion { got: version });
        }
        let kind = hdr.read_bits(8).expect("fixed header") as u8;
        if kind != KIND_COORDINATOR {
            return Err(SnapshotError::BadKind { got: kind });
        }

        let mut pre = Cursor::new(&bytes[HEADER_FIXED..]);
        let len = pre.varint()?;
        if len > MAX_SNAPSHOT as u64 {
            return Err(SnapshotError::Oversized { len, max: MAX_SNAPSHOT });
        }
        let len = len as usize;
        let body_at = HEADER_FIXED + pre.pos();
        let total = body_at + len + CRC_LEN;
        if bytes.len() < total {
            return Err(SnapshotError::Truncated { need: total, have: bytes.len() });
        }
        if bytes.len() > total {
            return Err(SnapshotError::Malformed("trailing bytes after snapshot"));
        }
        let mut crc_bytes = [0u8; CRC_LEN];
        crc_bytes.copy_from_slice(&bytes[total - CRC_LEN..]);
        let want = u32::from_le_bytes(crc_bytes);
        let got = crc32(&bytes[..total - CRC_LEN]);
        if want != got {
            return Err(SnapshotError::BadCrc { want, got });
        }

        let mut cur = Cursor::new(&bytes[body_at..body_at + len]);
        let fingerprint = cur.u64le()?;
        let dim = cur.count(MAX_DIM, "snapshot dim out of range")?;
        let workers = cur.count(MAX_WORKERS, "snapshot workers out of range")?;
        let rounds_total = cur.count(MAX_ROUNDS, "snapshot rounds out of range")?;
        if rounds_total == 0 {
            return Err(SnapshotError::Malformed("zero-round run"));
        }
        let next_round = cur.count(rounds_total, "next_round exceeds rounds_total")?;
        let phase = match cur.u8()? {
            0 => {
                let r = cur.varint()?;
                if next_round != 0 || r != 0 {
                    return Err(SnapshotError::Malformed("standby phase after completed rounds"));
                }
                SnapPhase::Standby
            }
            1 => {
                let r = cur.count(MAX_ROUNDS, "phase round out of range")?;
                if next_round == 0 || r != next_round - 1 {
                    return Err(SnapshotError::Malformed("phase round disagrees with next_round"));
                }
                SnapPhase::Broadcast(r)
            }
            _ => return Err(SnapshotError::Malformed("unknown phase tag")),
        };
        // v1 bodies have no selection tag: the four raw words follow the
        // phase directly. v2 bodies lead with the mode tag.
        let sel_tag = if version == SNAP_VERSION_V1 { 0 } else { cur.u8()? };
        let selection = match sel_tag {
            0 => {
                let mut raw = [0u64; 4];
                for w in raw.iter_mut() {
                    *w = cur.u64le()?;
                }
                if raw[2] & 1 == 0 {
                    return Err(SnapshotError::Malformed("even selection-rng increment"));
                }
                SelectionSnapshot::LegacyRaw(raw)
            }
            1 => {
                let mut commitment = [0u64; 4];
                for w in commitment.iter_mut() {
                    *w = cur.u64le()?;
                }
                let round = cur.varint()?;
                if round != next_round as u64 {
                    return Err(SnapshotError::Malformed(
                        "selection round disagrees with next_round",
                    ));
                }
                SelectionSnapshot::Committed { commitment, round }
            }
            _ => return Err(SnapshotError::Malformed("unknown selection tag")),
        };
        // Parameter (and residual) bytes are taken before any allocation,
        // so a hostile dim can never demand memory the file lacks.
        let pbytes = cur.take(4 * dim)?;
        let params: Vec<f32> = pbytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        let residual = match cur.u8()? {
            0 => None,
            1 => {
                let rbytes = cur.take(4 * dim)?;
                Some(
                    rbytes
                        .chunks_exact(4)
                        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                        .collect::<Vec<f32>>(),
                )
            }
            _ => return Err(SnapshotError::Malformed("bad residual flag")),
        };

        let nreports = cur.count(next_round, "report count exceeds next_round")?;
        if nreports != next_round {
            return Err(SnapshotError::Malformed("report count disagrees with next_round"));
        }
        let mut reports = Vec::new();
        for k in 0..nreports {
            let round = cur.count(MAX_ROUNDS, "report round out of range")?;
            if round != k {
                return Err(SnapshotError::Malformed("report rounds not contiguous"));
            }
            let lr = cur.f64()?;
            let train_loss = cur.f64()?;
            let eval = match cur.u8()? {
                0 => None,
                1 => Some((cur.f64()?, cur.f64()?)),
                _ => return Err(SnapshotError::Malformed("bad eval flag")),
            };
            let uplink_bits = cur.f64()?;
            let downlink_bits = cur.f64()?;
            let cum_uplink_bits = cur.f64()?;
            reports.push(RoundReport {
                round,
                lr,
                train_loss,
                eval,
                uplink_bits,
                downlink_bits,
                cum_uplink_bits,
            });
        }

        let nledger = cur.count(next_round, "ledger count exceeds next_round")?;
        if nledger != next_round {
            return Err(SnapshotError::Malformed("ledger count disagrees with next_round"));
        }
        let mut records = Vec::new();
        for _ in 0..nledger {
            let uplink_bits = cur.f64()?;
            let downlink_bits = cur.f64()?;
            let senders = cur.count(MAX_WORKERS, "ledger senders out of range")?;
            let uplink_nnz = cur.count(usize::MAX, "ledger nnz out of range")?;
            let uplink_wire_bytes = cur.varint()?;
            let downlink_wire_bytes = cur.varint()?;
            let stragglers = cur.count(MAX_WORKERS, "ledger stragglers out of range")?;
            let (shard_uplink_wire_bytes, shard_downlink_wire_bytes) = if version >= SNAP_VERSION {
                (cur.varint()?, cur.varint()?)
            } else {
                (0, 0)
            };
            records.push(RoundComm {
                uplink_bits,
                downlink_bits,
                senders,
                uplink_nnz,
                uplink_wire_bytes,
                downlink_wire_bytes,
                shard_uplink_wire_bytes,
                shard_downlink_wire_bytes,
                stragglers,
            });
        }
        let mut rejects = [0u64; REJECT_KINDS];
        if version >= SNAP_VERSION_V2 {
            for r in rejects.iter_mut() {
                *r = cur.varint()?;
            }
        }
        cur.done()?;

        Ok(CoordinatorSnapshot {
            fingerprint,
            dim,
            workers,
            rounds_total,
            phase,
            selection,
            params,
            residual,
            reports,
            ledger: CommLedger::from_records_with_rejects(records, rejects),
        })
    }

    /// Write the snapshot to `path` atomically: serialize, write to
    /// `<path>.tmp`, fsync, rename over `path`, fsync the parent
    /// directory (unix). A crash at any point leaves either the old file
    /// or the new one.
    pub fn save(&self, path: &Path) -> Result<(), SnapshotError> {
        use std::io::Write as _;
        let bytes = self.encode();
        let mut tmp_name = path.as_os_str().to_os_string();
        tmp_name.push(".tmp");
        let tmp = PathBuf::from(tmp_name);
        {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&bytes)?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)?;
        #[cfg(unix)]
        {
            let dir = match path.parent() {
                Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
                _ => PathBuf::from("."),
            };
            if let Ok(d) = std::fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
        Ok(())
    }

    /// Load and validate a snapshot file. The file's metadata length is
    /// checked against [`MAX_SNAPSHOT`] *before* the read, so a hostile
    /// path cannot force a giant allocation.
    pub fn load(path: &Path) -> Result<Self, SnapshotError> {
        let meta = std::fs::metadata(path)?;
        let cap = (MAX_SNAPSHOT + HEADER_FIXED + CRC_LEN + 10) as u64;
        if meta.len() > cap {
            return Err(SnapshotError::Oversized { len: meta.len(), max: MAX_SNAPSHOT });
        }
        let bytes = std::fs::read(path)?;
        Self::decode(&bytes)
    }
}

/// FNV-1a 64-bit — the run-configuration fingerprint hash (stable across
/// processes; not cryptographic, it only guards against *accidental*
/// config drift between a snapshot and the run resuming from it).
pub fn fingerprint_bytes(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(next: usize) -> CoordinatorSnapshot {
        let dim = 5;
        let reports: Vec<RoundReport> = (0..next)
            .map(|t| RoundReport {
                round: t,
                lr: 0.05,
                train_loss: 1.0 / (t + 1) as f64,
                eval: if t % 2 == 0 { Some((0.5, 0.75)) } else { None },
                uplink_bits: 100.0,
                downlink_bits: 10.0,
                cum_uplink_bits: 100.0 * (t + 1) as f64,
            })
            .collect();
        let mut ledger = CommLedger::new();
        for t in 0..next {
            ledger.record(RoundComm {
                uplink_bits: 100.0,
                downlink_bits: 10.0,
                senders: 4,
                uplink_nnz: 3 + t,
                uplink_wire_bytes: 256,
                downlink_wire_bytes: 128,
                shard_uplink_wire_bytes: (t as u64) * 48,
                shard_downlink_wire_bytes: (t as u64) * 32,
                stragglers: t % 2,
            });
        }
        CoordinatorSnapshot {
            fingerprint: 0xdead_beef_cafe_f00d,
            dim,
            workers: 4,
            rounds_total: next.max(1) + 2,
            phase: if next == 0 { SnapPhase::Standby } else { SnapPhase::Broadcast(next - 1) },
            selection: SelectionSnapshot::LegacyRaw(crate::util::rng::Pcg64::seed_from(7).to_raw()),
            params: (0..dim).map(|i| i as f32 * 0.25 - 0.5).collect(),
            residual: Some(vec![0.125; dim]),
            reports,
            ledger,
        }
    }

    #[test]
    fn roundtrip_bit_identical() {
        for next in [0usize, 1, 3] {
            let snap = sample(next);
            let bytes = snap.encode();
            let back = CoordinatorSnapshot::decode(&bytes).expect("decode");
            assert_eq!(back, snap, "next={next}");
            assert_eq!(back.next_round(), next);
        }
    }

    #[test]
    fn save_is_atomic_and_loads_back() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("sparsignd-snap-test-{}.bin", std::process::id()));
        let snap = sample(2);
        snap.save(&path).expect("save");
        // No temp residue, and the load revalidates to the same value.
        let mut tmp_name = path.as_os_str().to_os_string();
        tmp_name.push(".tmp");
        assert!(!PathBuf::from(tmp_name).exists(), "tmp file left behind");
        let back = CoordinatorSnapshot::load(&path).expect("load");
        assert_eq!(back, snap);
        // Overwrite with a later snapshot; the file is replaced whole.
        let later = sample(3);
        later.save(&path).expect("resave");
        assert_eq!(CoordinatorSnapshot::load(&path).expect("reload"), later);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn header_and_crc_failures_are_typed() {
        let good = sample(1).encode();

        let mut bad = good.clone();
        bad[0] ^= 0xff;
        assert!(matches!(
            CoordinatorSnapshot::decode(&bad),
            Err(SnapshotError::BadMagic { .. })
        ));

        let mut bad = good.clone();
        bad[4] = SNAP_VERSION + 1;
        assert!(matches!(
            CoordinatorSnapshot::decode(&bad),
            Err(SnapshotError::BadVersion { got }) if got == SNAP_VERSION + 1
        ));

        let mut bad = good.clone();
        bad[5] = 0x7f;
        assert!(matches!(
            CoordinatorSnapshot::decode(&bad),
            Err(SnapshotError::BadKind { got: 0x7f })
        ));

        let mut bad = good.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x04;
        assert!(matches!(CoordinatorSnapshot::decode(&bad), Err(SnapshotError::BadCrc { .. })));

        for cut in 0..good.len() {
            let err = CoordinatorSnapshot::decode(&good[..cut]).unwrap_err();
            assert!(matches!(err, SnapshotError::Truncated { .. }), "cut {cut}: {err}");
        }

        let mut long = good.clone();
        long.push(0);
        assert!(matches!(
            CoordinatorSnapshot::decode(&long),
            Err(SnapshotError::Malformed("trailing bytes after snapshot"))
        ));
    }

    #[test]
    fn hostile_lengths_are_capped_before_allocation() {
        // A gigantic declared body length is refused up front.
        let mut hostile = Vec::new();
        let mut hdr = BitWriter::new();
        hdr.push_bits(SNAP_MAGIC as u64, 32);
        hdr.push_bits(SNAP_VERSION as u64, 8);
        hdr.push_bits(KIND_COORDINATOR as u64, 8);
        hostile.extend_from_slice(hdr.as_bytes());
        push_varint(&mut hostile, u64::MAX / 2);
        hostile.extend_from_slice(&[0u8; 32]);
        assert!(matches!(
            CoordinatorSnapshot::decode(&hostile),
            Err(SnapshotError::Oversized { .. })
        ));
    }

    #[test]
    fn phase_and_rng_consistency_is_enforced() {
        // Standby with completed rounds must be rejected: re-encode a
        // 1-round snapshot with a lying phase tag.
        let mut snap = sample(1);
        snap.phase = SnapPhase::Standby;
        let bytes = snap.encode();
        assert!(matches!(
            CoordinatorSnapshot::decode(&bytes),
            Err(SnapshotError::Malformed("standby phase after completed rounds"))
        ));

        let mut snap = sample(2);
        snap.phase = SnapPhase::Broadcast(0); // should be Broadcast(1)
        let bytes = snap.encode();
        assert!(matches!(
            CoordinatorSnapshot::decode(&bytes),
            Err(SnapshotError::Malformed("phase round disagrees with next_round"))
        ));

        let mut snap = sample(1);
        match &mut snap.selection {
            SelectionSnapshot::LegacyRaw(raw) => raw[2] &= !1,
            _ => unreachable!("sample uses legacy selection"),
        }
        let bytes = snap.encode();
        assert!(matches!(
            CoordinatorSnapshot::decode(&bytes),
            Err(SnapshotError::Malformed("even selection-rng increment"))
        ));

        // A committed round counter must agree with next_round.
        let mut snap = sample(2);
        snap.selection = SelectionSnapshot::Committed { commitment: [1, 2, 3, 4], round: 5 };
        let bytes = snap.encode();
        assert!(matches!(
            CoordinatorSnapshot::decode(&bytes),
            Err(SnapshotError::Malformed("selection round disagrees with next_round"))
        ));
    }

    #[test]
    fn committed_selection_roundtrips_without_raw_state() {
        let mut snap = sample(3);
        let commitment = crate::util::rng::selection_commitment(
            &crate::util::rng::selection_root_key(7),
        );
        snap.selection = SelectionSnapshot::Committed { commitment, round: 3 };
        snap.ledger.add_rejects(&[0, 1, 0, 2, 0, 0]);
        let bytes = snap.encode();
        let back = CoordinatorSnapshot::decode(&bytes).expect("decode");
        assert_eq!(back, snap);
        assert_eq!(back.ledger.total_rejects(), 3);
        // The raw Pcg64 words for seed 7 must not appear anywhere in the
        // file: hardened snapshots leak no generator state.
        for w in crate::util::rng::Pcg64::seed_from(7).to_raw() {
            let needle = w.to_le_bytes();
            assert!(
                !bytes.windows(8).any(|win| win == needle),
                "raw selection word {w:#x} leaked into a committed snapshot"
            );
        }
    }

    /// Hand-encode `snap` in a legacy grammar: v1 (no selection tag, no
    /// reject counters) or v2 (selection tag + rejects, no shard-tier
    /// ledger columns). Kept independent of `encode()` so these tests
    /// pin the historical layouts, not whatever the writer does today.
    fn encode_legacy(snap: &CoordinatorSnapshot, version: u8) -> Vec<u8> {
        assert!(version == SNAP_VERSION_V1 || version == SNAP_VERSION_V2);
        let raw = match snap.selection {
            SelectionSnapshot::LegacyRaw(raw) => raw,
            _ => unreachable!(),
        };
        let mut body = Vec::new();
        body.extend_from_slice(&snap.fingerprint.to_le_bytes());
        push_varint(&mut body, snap.dim as u64);
        push_varint(&mut body, snap.workers as u64);
        push_varint(&mut body, snap.rounds_total as u64);
        push_varint(&mut body, snap.reports.len() as u64);
        match snap.phase {
            SnapPhase::Standby => {
                body.push(0);
                push_varint(&mut body, 0);
            }
            SnapPhase::Broadcast(t) => {
                body.push(1);
                push_varint(&mut body, t as u64);
            }
        }
        if version >= SNAP_VERSION_V2 {
            body.push(0); // selection tag: legacy raw words
        }
        for w in raw {
            body.extend_from_slice(&w.to_le_bytes());
        }
        for &x in &snap.params {
            body.extend_from_slice(&x.to_le_bytes());
        }
        match &snap.residual {
            None => body.push(0),
            Some(r) => {
                body.push(1);
                for &x in r {
                    body.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        push_varint(&mut body, snap.reports.len() as u64);
        for r in &snap.reports {
            push_varint(&mut body, r.round as u64);
            body.extend_from_slice(&r.lr.to_le_bytes());
            body.extend_from_slice(&r.train_loss.to_le_bytes());
            match r.eval {
                None => body.push(0),
                Some((l, a)) => {
                    body.push(1);
                    body.extend_from_slice(&l.to_le_bytes());
                    body.extend_from_slice(&a.to_le_bytes());
                }
            }
            body.extend_from_slice(&r.uplink_bits.to_le_bytes());
            body.extend_from_slice(&r.downlink_bits.to_le_bytes());
            body.extend_from_slice(&r.cum_uplink_bits.to_le_bytes());
        }
        push_varint(&mut body, snap.ledger.rounds() as u64);
        for rec in snap.ledger.records() {
            body.extend_from_slice(&rec.uplink_bits.to_le_bytes());
            body.extend_from_slice(&rec.downlink_bits.to_le_bytes());
            push_varint(&mut body, rec.senders as u64);
            push_varint(&mut body, rec.uplink_nnz as u64);
            push_varint(&mut body, rec.uplink_wire_bytes);
            push_varint(&mut body, rec.downlink_wire_bytes);
            push_varint(&mut body, rec.stragglers as u64);
        }
        if version >= SNAP_VERSION_V2 {
            for &n in snap.ledger.rejects_by_kind() {
                push_varint(&mut body, n);
            }
        }
        let mut out = Vec::new();
        let mut hdr = BitWriter::new();
        hdr.push_bits(SNAP_MAGIC as u64, 32);
        hdr.push_bits(version as u64, 8);
        hdr.push_bits(KIND_COORDINATOR as u64, 8);
        out.extend_from_slice(hdr.as_bytes());
        push_varint(&mut out, body.len() as u64);
        out.extend_from_slice(&body);
        let crc = crc32(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    /// `snap` with the shard-tier ledger columns zeroed — what loading a
    /// pre-v3 file must reconstruct.
    fn without_shard_columns(snap: &CoordinatorSnapshot) -> CoordinatorSnapshot {
        let recs: Vec<RoundComm> = snap
            .ledger
            .records()
            .iter()
            .map(|r| RoundComm {
                shard_uplink_wire_bytes: 0,
                shard_downlink_wire_bytes: 0,
                ..*r
            })
            .collect();
        let mut out = snap.clone();
        out.ledger = CommLedger::from_records_with_rejects(recs, *snap.ledger.rejects_by_kind());
        out
    }

    #[test]
    fn v1_snapshots_still_load() {
        // Re-encode sample(2) in the version-1 grammar by hand: no
        // selection tag (raw words follow the phase), no reject
        // counters, no shard-tier columns. The loader must accept it.
        let snap = sample(2);
        let v1 = encode_legacy(&snap, SNAP_VERSION_V1);
        let back = CoordinatorSnapshot::decode(&v1).expect("v1 decode");
        assert_eq!(back, without_shard_columns(&snap));
        assert_eq!(back.ledger.total_rejects(), 0);
    }

    #[test]
    fn v2_snapshots_still_load() {
        // Version-2 grammar: selection tag + reject counters, but no
        // shard-tier ledger columns. The reject counters must survive
        // the load (the v3 bump must not steal v2's reject gate).
        let mut snap = sample(2);
        snap.ledger.add_rejects(&[0, 2, 0, 1, 0, 0]);
        let v2 = encode_legacy(&snap, SNAP_VERSION_V2);
        let back = CoordinatorSnapshot::decode(&v2).expect("v2 decode");
        assert_eq!(back, without_shard_columns(&snap));
        assert_eq!(back.ledger.total_rejects(), 3);
    }

    #[test]
    fn fingerprint_is_stable() {
        assert_eq!(fingerprint_bytes(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fingerprint_bytes(b"a"), fingerprint_bytes(b"a"));
        assert_ne!(fingerprint_bytes(b"a"), fingerprint_bytes(b"b"));
    }
}
