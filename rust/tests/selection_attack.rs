//! The selection-prediction attack, end to end (DESIGN.md §13).
//!
//! Legacy `Pcg64` selection serializes its raw generator state into
//! coordinator snapshots, so an attacker holding one snapshot file
//! predicts every future cohort exactly. The hardened committed-seed
//! mode serializes only a one-way commitment — the same attacker gets
//! nothing better than a blind guess — while keeping the elastic
//! contract: hardened runs snapshot/resume bit-identically.

use sparsignd::compressors::CompressorKind;
use sparsignd::coordinator::prediction::SelectionAttacker;
use sparsignd::coordinator::{
    AggregationRule, Algorithm, ClassifierEnv, RunHistory, SelectionMode, SelectionRng,
    SelectionSnapshot, TrainingRun, WorkerSampler,
};
use sparsignd::data::{DirichletPartitioner, SyntheticSpec, SyntheticTask};
use sparsignd::model::ModelKind;
use sparsignd::optim::LrSchedule;
use sparsignd::snapshot::{CoordinatorSnapshot, SnapshotError, SnapshotPolicy};
use sparsignd::util::rng::Pcg64;

fn env(workers: usize) -> ClassifierEnv {
    let task = SyntheticTask::generate(
        SyntheticSpec {
            dim: 12,
            classes: 3,
            modes: 1,
            separation: 1.8,
            noise: 0.25,
            label_noise: 0.0,
            train: 480,
            test: 120,
        },
        61,
    );
    let mut rng = Pcg64::seed_from(62);
    let fed = DirichletPartitioner { alpha: 0.5, workers }.partition(&task.train, &mut rng);
    ClassifierEnv::new(
        ModelKind::Linear { inputs: 12, classes: 3 }.build(),
        task.train,
        task.test,
        fed,
        16,
    )
}

fn sampled_run(mode: SelectionMode, rounds: usize, seed: u64) -> TrainingRun {
    let mut run = TrainingRun::new(
        Algorithm::CompressedGd {
            compressor: CompressorKind::Sign,
            aggregation: AggregationRule::MajorityVote,
        },
        LrSchedule::Const { lr: 0.05 },
        rounds,
    );
    run.participation = 0.5;
    run.eval_every = 0;
    run.seed = seed;
    run.selection = mode;
    run
}

fn assert_identical(a: &RunHistory, b: &RunHistory) {
    assert_eq!(a.final_params, b.final_params, "final params");
    assert_eq!(a.reports, b.reports, "round reports");
    assert_eq!(a.ledger, b.ledger, "communication ledger");
}

fn snap_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("sparsignd-selattack-{}-{tag}.snap", std::process::id()))
}

/// The true selection stream a run with this seed/mode draws, replayed
/// independently of any snapshot (the ground truth an observer of the
/// run's cohorts would have recorded).
fn true_cohorts(
    mode: SelectionMode,
    seed: u64,
    workers: usize,
    participation: f64,
    rounds: usize,
) -> Vec<Vec<usize>> {
    let sampler = WorkerSampler::new(workers, participation);
    let root = Pcg64::new(seed, 0xc0_0e_d1);
    let mut sel = SelectionRng::from_seed(mode, &root, seed);
    let mut buf = Vec::new();
    (0..rounds)
        .map(|t| {
            sel.select_into(&sampler, t, &mut buf);
            buf.clone()
        })
        .collect()
}

/// Legacy mode: one leaked snapshot file ⇒ exact prediction of every
/// future cohort. This is the attack the committed mode closes.
#[test]
fn legacy_snapshot_predicts_future_cohorts_exactly() {
    let workers = 16;
    let e = env(workers);
    let mut rng = Pcg64::seed_from(63);
    let init = e.init_params(&mut rng);
    // 7 rounds with a period-4 policy: exactly one snapshot (round 4)
    // survives on disk, with three attackable rounds still ahead.
    let run = sampled_run(SelectionMode::Legacy, 7, 21);
    let path = snap_path("legacy");

    let policy = SnapshotPolicy::every(&path, 4);
    run.run_snapshotted(&e, init, &|p| e.evaluate(p), &policy).expect("snapshotted run");
    let snap = CoordinatorSnapshot::load(&path).expect("stolen snapshot");
    assert_eq!(snap.next_round(), 4);

    let attacker = SelectionAttacker {
        workers,
        participation: run.participation,
        transcript: Vec::new(), // not needed: the raw state is in hand
    };
    let predicted = attacker
        .predict_from_snapshot(&snap, 3)
        .expect("legacy snapshots hand over the generator");
    let truth = true_cohorts(SelectionMode::Legacy, run.seed, workers, run.participation, 7);
    assert_eq!(predicted.as_slice(), &truth[4..7], "prediction must be exact");
    let k = WorkerSampler::new(workers, run.participation).per_round();
    for (p, t) in predicted.iter().zip(&truth[4..7]) {
        assert_eq!(SelectionAttacker::overlap(p, t), k, "every round fully predicted");
    }
    let _ = std::fs::remove_file(&path);
}

/// Committed mode against the *same* attacker: the snapshot yields no
/// generator state, and the best fallback — predicting from a wrong
/// seed — scores at chance level (≈ k²/M per round), nowhere near the
/// exact-k score the legacy leak gives.
#[test]
fn hardened_snapshot_defeats_the_same_attacker() {
    let workers = 16;
    let e = env(workers);
    let mut rng = Pcg64::seed_from(64);
    let init = e.init_params(&mut rng);
    // True seed far outside any enumeration budget the test models.
    let seed = 0x9e37_79b9_7f4a_7c15;
    let run = sampled_run(SelectionMode::Committed, 8, seed);
    let path = snap_path("hardened");

    let policy = SnapshotPolicy::every(&path, 4);
    run.run_snapshotted(&e, init, &|p| e.evaluate(p), &policy).expect("snapshotted run");
    let snap = CoordinatorSnapshot::load(&path).expect("stolen snapshot");
    assert!(
        matches!(snap.selection, SelectionSnapshot::Committed { .. }),
        "hardened snapshots must not carry raw selection state"
    );

    let attacker =
        SelectionAttacker { workers: 60, participation: 0.25, transcript: Vec::new() };
    assert!(
        attacker.predict_from_snapshot(&snap, 4).is_none(),
        "the commitment must yield no prediction"
    );

    // Statistical half, at population scale: a wrong-seed guesser's
    // per-round overlap with the true hardened stream averages ≈ k²/M
    // (chance), not k (the legacy-leak score). 200 rounds of k=15 of
    // M=60: chance mean 3.75, exact mean 15. The 2.0 margin holds with
    // overwhelming slack (per-round overlap is hypergeometric with
    // σ ≈ 1.6, and the mean of 200 rounds concentrates hard).
    let (m, p, rounds) = (60usize, 0.25f64, 200usize);
    let truth = true_cohorts(SelectionMode::Committed, seed, m, p, rounds);
    let guess = true_cohorts(SelectionMode::Committed, 1234, m, p, rounds);
    let k = WorkerSampler::new(m, p).per_round();
    let chance = (k * k) as f64 / m as f64;
    let mean = truth
        .iter()
        .zip(&guess)
        .map(|(t, g)| SelectionAttacker::overlap(g, t) as f64)
        .sum::<f64>()
        / rounds as f64;
    assert!(
        (mean - chance).abs() < 2.0,
        "wrong-seed attacker should be at chance ≈ {chance:.2}, got {mean:.2}"
    );
    assert!(mean < k as f64 / 2.0, "nowhere near the exact-prediction score {k}");
    let _ = std::fs::remove_file(&path);
}

/// Hardening must not cost the elastic contract: a hardened run
/// interrupted by a snapshot resumes bit-identically — across engines
/// (serial snapshotter, pool resumer).
#[test]
fn hardened_mode_snapshot_resume_is_bit_identical() {
    let e = env(10);
    let mut rng = Pcg64::seed_from(65);
    let init = e.init_params(&mut rng);
    let path = snap_path("resume");

    let mut serial = sampled_run(SelectionMode::Committed, 6, 33);
    serial.eval_every = 3;
    serial.threads = Some(1);
    let plain = serial.run(&e, init.clone(), &|p| e.evaluate(p));
    let policy = SnapshotPolicy::every(&path, 3);
    let snapped = serial
        .run_snapshotted(&e, init.clone(), &|p| e.evaluate(p), &policy)
        .expect("snapshotted run");
    assert_identical(&plain, &snapped);

    let snap = CoordinatorSnapshot::load(&path).expect("load");
    assert_eq!(snap.next_round(), 3);
    let mut pooled = sampled_run(SelectionMode::Committed, 6, 33);
    pooled.eval_every = 3;
    pooled.threads = Some(4);
    let resumed = pooled.resume_from(&e, snap, &|p| e.evaluate(p), None).expect("resume");
    assert_identical(&plain, &resumed);
    let _ = std::fs::remove_file(&path);
}

/// A hardened run refuses to restore raw generator state: splicing a
/// legacy-raw selection record into a committed run's snapshot (or the
/// reverse) is a mode mismatch, not a silent downgrade. Property-tested
/// over seeds.
#[test]
fn raw_state_does_not_round_trip_into_a_hardened_run() {
    let e = env(8);
    let mut rng = Pcg64::seed_from(66);
    let init = e.init_params(&mut rng);
    let run = sampled_run(SelectionMode::Committed, 4, 9);
    let path = snap_path("tamper");
    let policy = SnapshotPolicy::every(&path, 2);
    run.run_snapshotted(&e, init, &|p| e.evaluate(p), &policy).expect("snapshotted run");
    let snap = CoordinatorSnapshot::load(&path).expect("load");

    let mut seed_rng = Pcg64::seed_from(67);
    for _ in 0..32 {
        // Attacker splices arbitrary raw Pcg64 state into the snapshot,
        // hoping the coordinator will adopt a generator it controls.
        let mut tampered = snap.clone();
        let raw_seed = seed_rng.next_u64();
        tampered.selection = SelectionSnapshot::LegacyRaw(Pcg64::seed_from(raw_seed).to_raw());
        let err = run
            .resume_from(&e, tampered, &|p| e.evaluate(p), None)
            .expect_err("raw selection state must be refused in hardened mode");
        assert!(matches!(err, SnapshotError::Malformed(_)), "{err}");
    }
    // The reverse splice (commitment into a legacy run) is refused too.
    let legacy = sampled_run(SelectionMode::Legacy, 4, 9);
    let legacy_path = snap_path("tamper-legacy");
    let mut rng2 = Pcg64::seed_from(68);
    let init2 = e.init_params(&mut rng2);
    let policy2 = SnapshotPolicy::every(&legacy_path, 2);
    legacy
        .run_snapshotted(&e, init2, &|p| e.evaluate(p), &policy2)
        .expect("legacy snapshotted run");
    let mut crossed = CoordinatorSnapshot::load(&legacy_path).expect("load");
    crossed.selection = snap.selection;
    let err = legacy
        .resume_from(&e, crossed, &|p| e.evaluate(p), None)
        .expect_err("commitment must be refused in legacy mode");
    assert!(matches!(err, SnapshotError::Malformed(_)), "{err}");
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&legacy_path);
}
