//! Byzantine behaviour over the real transport (DESIGN.md §13): the
//! malicious-agent mode of `net::client` enacts protocol-level attacks
//! against the coordinator's actual framing, and the coordinator answers
//! with typed rejects — the run completes without stalling or panicking.
//!
//! * Equivocation (duplicate + stale-replay frames) → `Duplicate` /
//!   `BadRound` / `Late` rejects, over both TCP and (on unix) UDS.
//! * Adaptive stragglers → straggler marks plus `Late`/`BadRound`
//!   rejects, with honest co-hosted workers served first.
//! * Gradient-level attacks (collusive sign-flip) need no protocol
//!   defense and must stay **bit-identical** between the wire and the
//!   in-process engine — the attack rides inside `worker_round`.
//! * Payload-level garbage (wrong-dimension frames) is a contract
//!   violation, not a reject: the hostile peer is hung up on and the
//!   run recovers through the dead-range bookkeeping.

use sparsignd::compressors::{CompressedGrad, CompressorKind, PackedTernary};
use sparsignd::coordinator::{
    AggregationRule, Algorithm, AttackPlan, ClassifierEnv, RunHistory, TrainingRun,
};
use sparsignd::data::{DirichletPartitioner, SyntheticSpec, SyntheticTask};
use sparsignd::model::ModelKind;
use sparsignd::net::client::loopback_endpoint;
use sparsignd::net::wire::{self, WireBuf};
use sparsignd::net::{
    read_frame_bytes, run_fleet, run_loopback, Endpoint, FleetOptions, Msg, NetCoordinator,
    RejectReason, ServeOptions,
};
use sparsignd::optim::LrSchedule;
use sparsignd::util::rng::Pcg64;
use std::io::Write as _;
use std::net::TcpStream;
use std::time::Duration;

/// Cumulative count of one reject kind from the ledger's per-kind array.
fn kind(by_kind: &[u64], r: RejectReason) -> u64 {
    by_kind[r.index()]
}

fn env(workers: usize) -> ClassifierEnv {
    let task = SyntheticTask::generate(
        SyntheticSpec {
            dim: 10,
            classes: 3,
            modes: 1,
            separation: 1.8,
            noise: 0.25,
            label_noise: 0.0,
            train: 360,
            test: 90,
        },
        71,
    );
    let mut rng = Pcg64::seed_from(72);
    let fed = DirichletPartitioner { alpha: 0.5, workers }.partition(&task.train, &mut rng);
    ClassifierEnv::new(
        ModelKind::Linear { inputs: 10, classes: 3 }.build(),
        task.train,
        task.test,
        fed,
        16,
    )
}

fn base_run(rounds: usize) -> TrainingRun {
    let mut run = TrainingRun::new(
        Algorithm::CompressedGd {
            compressor: CompressorKind::Sign,
            aggregation: AggregationRule::MajorityVote,
        },
        LrSchedule::Const { lr: 0.05 },
        rounds,
    );
    run.eval_every = 0;
    run.seed = 11;
    run
}

/// Equivocating cohort over a live loopback transport: every round each
/// equivocator sends its honest update, a byte-identical duplicate and a
/// stale-round replay. The run must complete all rounds with the abuse
/// confined to typed rejects.
fn equivocation_round_trip(uds: bool) {
    let workers = 8;
    let rounds = 4;
    let e = env(workers);
    let mut rng = Pcg64::seed_from(73);
    let init = e.init_params(&mut rng);
    let mut run = base_run(rounds);
    let equivocators = 2u64;
    run.attack = Some(AttackPlan::parse("equivocate:2", workers, run.seed).expect("spec"));

    let serve_opts = ServeOptions::new(loopback_endpoint(uds));
    let fleet_opts = FleetOptions { agents: 2, ..FleetOptions::default() };
    let eval = |p: &[f32]| e.evaluate(p);
    let (hist, stats) =
        run_loopback(&run, &e, init, &eval, serve_opts, &fleet_opts).expect("attacked run");

    assert_eq!(hist.reports.len(), rounds, "every round completed");
    assert!(hist.final_params.iter().all(|v| v.is_finite()));
    // Honest updates all landed: no straggler marks, full senders.
    assert_eq!(hist.ledger.total_stragglers(), 0);
    for t in 0..rounds {
        assert_eq!(hist.ledger.get(t).unwrap().senders, workers, "round {t}");
    }

    // Each equivocator sends two bad frames per round (duplicate +
    // stale replay); rejects issued while the final round tears down may
    // race the last ledger fold, so the floor excludes one round.
    let by_kind = hist.ledger.rejects_by_kind();
    let total = hist.ledger.total_rejects();
    let per_round = 2 * equivocators;
    assert!(
        total >= per_round * (rounds as u64 - 1) && total <= per_round * rounds as u64,
        "expected ~{} typed rejects, got {total} ({by_kind:?})",
        per_round * rounds as u64
    );
    // Every reject is one of the equivocation shapes; nothing leaked
    // into the identity/selection kinds.
    assert_eq!(kind(by_kind, RejectReason::NotSelected), 0, "{by_kind:?}");
    assert_eq!(kind(by_kind, RejectReason::UnknownWorker), 0, "{by_kind:?}");
    assert_eq!(kind(by_kind, RejectReason::WrongClient), 0, "{by_kind:?}");
    let equivocation_kinds = kind(by_kind, RejectReason::BadRound)
        + kind(by_kind, RejectReason::Duplicate)
        + kind(by_kind, RejectReason::Late);
    assert_eq!(equivocation_kinds, total, "{by_kind:?}");
    assert!(kind(by_kind, RejectReason::Duplicate) > 0, "duplicates typed: {by_kind:?}");
    assert!(kind(by_kind, RejectReason::BadRound) > 0, "stale replays typed: {by_kind:?}");
    // The fleet saw its abuse answered (rejects from completed rounds
    // are always read back before `Fin`).
    assert!(stats.rejected > 0);
}

#[test]
fn equivocating_cohort_draws_typed_rejects_over_tcp() {
    equivocation_round_trip(false);
}

#[cfg(unix)]
#[test]
fn equivocating_cohort_draws_typed_rejects_over_uds() {
    equivocation_round_trip(true);
}

/// Adaptive straggler cohort: holds its (honest) update past every
/// announced deadline. Each round closes on time, marks the straggler
/// and types its late frame `BadRound`/`Late`; honest workers co-hosted
/// on the same agent are unaffected.
#[test]
fn adaptive_straggler_is_marked_and_typed_each_round() {
    let workers = 6;
    let rounds = 3;
    let e = env(workers);
    let mut rng = Pcg64::seed_from(74);
    let init = e.init_params(&mut rng);
    let mut run = base_run(rounds);
    run.attack = Some(AttackPlan::parse("straggle:1:100", workers, run.seed).expect("spec"));

    let mut serve_opts = ServeOptions::new(loopback_endpoint(false));
    serve_opts.round_deadline = Some(Duration::from_millis(500));
    let fleet_opts = FleetOptions { agents: 2, ..FleetOptions::default() };
    let coordinator = NetCoordinator::bind(serve_opts).expect("bind");
    let ep = coordinator.local_endpoint().clone();
    let mut hist: Option<RunHistory> = None;
    std::thread::scope(|s| {
        let handle = s.spawn(|| coordinator.serve(&run, workers, init, &|p| e.evaluate(p)));
        // The straggler sleeps through the final round's teardown and
        // then writes into a closed socket, so its agent may error out
        // after `Fin` — the server-side history is the acceptance
        // signal, not the fleet result.
        let _ = run_fleet(&ep, &run, &e, &fleet_opts);
        hist = Some(handle.join().expect("server thread").expect("serve"));
    });
    let hist = hist.unwrap();

    assert_eq!(hist.reports.len(), rounds, "deadline keeps every round moving");
    assert!(hist.final_params.iter().all(|v| v.is_finite()));
    // One straggler mark per round (more only if the harness itself ran
    // slow enough for an honest worker to miss a deadline).
    assert!(
        hist.ledger.total_stragglers() >= rounds,
        "straggler must be marked every round, got {}",
        hist.ledger.total_stragglers()
    );
    // Its held-back frames land after the rounds close: all typed as
    // `Late`/`BadRound`, nothing else. The final round's frame hits the
    // torn-down socket, so the floor is rounds - 1.
    let by_kind = hist.ledger.rejects_by_kind();
    let total = hist.ledger.total_rejects();
    assert!(total >= rounds as u64 - 1, "late frames must be typed, got {by_kind:?}");
    let late_kinds = kind(by_kind, RejectReason::BadRound) + kind(by_kind, RejectReason::Late);
    assert_eq!(late_kinds, total, "{by_kind:?}");
}

/// Gradient-level attacks ride inside `worker_round`, so an attacked
/// wire run is *bit-identical* to the attacked in-process run — and
/// draws no rejects: the transport has nothing to defend against.
#[test]
fn collusive_sign_flip_over_the_wire_matches_the_engine() {
    let workers = 10;
    let e = env(workers);
    let mut rng = Pcg64::seed_from(75);
    let init = e.init_params(&mut rng);
    let mut run = base_run(5);
    run.algorithm = Algorithm::CompressedGd {
        compressor: CompressorKind::Sparsign { budget: 1.0 },
        aggregation: AggregationRule::MajorityVote,
    };
    run.attack = Some(AttackPlan::parse("collusive:30%", workers, run.seed).expect("spec"));

    let in_process = run.run(&e, init.clone(), &|p| e.evaluate(p));
    let serve_opts = ServeOptions::new(loopback_endpoint(false));
    let fleet_opts = FleetOptions { agents: 3, ..FleetOptions::default() };
    let eval = |p: &[f32]| e.evaluate(p);
    let (wire_hist, stats) =
        run_loopback(&run, &e, init, &eval, serve_opts, &fleet_opts).expect("loopback run");

    assert_eq!(in_process.final_params, wire_hist.final_params, "final params");
    assert_eq!(in_process.reports.len(), wire_hist.reports.len());
    for (ra, rb) in in_process.reports.iter().zip(&wire_hist.reports) {
        assert_eq!(ra.train_loss, rb.train_loss, "round {}", ra.round);
        assert_eq!(ra.uplink_bits, rb.uplink_bits, "round {}", ra.round);
    }
    assert_eq!(wire_hist.ledger.total_rejects(), 0, "no protocol misbehaviour");
    assert_eq!(wire_hist.ledger.total_stragglers(), 0);
    assert_eq!(stats.rejected, 0);
}

/// A hand-driven wire client: speaks raw frames over TCP so the test
/// controls exactly what the server sees — honestly for the workers it
/// covers, or hostilely for the garbage-payload probe.
struct RawWire {
    stream: TcpStream,
    wbuf: WireBuf,
    out: Vec<u8>,
    buf: Vec<u8>,
}

impl RawWire {
    fn connect(ep: &Endpoint) -> Self {
        let Endpoint::Tcp(addr) = ep else { panic!("garbage test speaks tcp") };
        let stream = TcpStream::connect(addr).expect("connect");
        stream.set_nodelay(true).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        Self { stream, wbuf: WireBuf::new(), out: Vec::new(), buf: Vec::new() }
    }

    fn send(&mut self, msg: &Msg) {
        self.out.clear();
        self.wbuf.encode(msg, &mut self.out);
        self.stream.write_all(&self.out).expect("send frame");
    }

    /// A protocol-valid update frame whose ternary payload has dimension
    /// `d` — pass the run's true dimension for an honest submission, or
    /// any other value for the payload-contract violation the server
    /// answers with a hangup rather than a typed reject.
    fn send_update(&mut self, t: u64, worker: u64, d: usize) {
        let pack = PackedTernary::dense_signs(&vec![0.5f32; d], 1.0);
        let grad = CompressedGrad::ternary(pack, 2.0 * d as f64);
        self.out.clear();
        self.wbuf.encode_update(t, worker, 0.25, &grad, &mut self.out);
        self.stream.write_all(&self.out).expect("send update");
    }

    fn recv(&mut self) -> Option<Msg> {
        let n = read_frame_bytes(&mut self.stream, wire::MAX_PAYLOAD, &mut self.buf).ok()?;
        let (frame, _) = wire::parse_frame(&self.buf[..n], wire::MAX_PAYLOAD).ok()?;
        wire::decode_msg(frame).ok()
    }

    fn join(&mut self, lo: u64, hi: u64, cfg: u64) {
        self.send(&Msg::Hello { lo, hi, cfg, env: 0 });
        assert!(matches!(self.recv(), Some(Msg::Welcome { .. })), "expected Welcome");
    }

    fn expect_round(&mut self) -> (u64, Vec<u64>) {
        match self.recv() {
            Some(Msg::RoundOpen { t, selected, .. }) => (t, selected),
            other => panic!("expected RoundOpen, got {other:?}"),
        }
    }
}

/// Wrong-dimension update frames break the payload contract: the server
/// hangs up on the sender (no typed reject, no panic), releases its
/// claimed range through the dead-conn bookkeeping, and the honest rest
/// of the fleet finishes the run.
#[test]
fn garbage_payload_is_hung_up_on_and_the_run_survives() {
    let workers = 3;
    let rounds = 2;
    let d = 10;
    let e = env(workers);
    let mut rng = Pcg64::seed_from(76);
    let init = e.init_params(&mut rng);
    let run = base_run(rounds);
    let cfg = run.config_fingerprint(d, workers, 0);

    let opts = ServeOptions::new(Endpoint::Tcp("127.0.0.1:0".into()));
    let coordinator = NetCoordinator::bind(opts).expect("bind");
    let ep = coordinator.local_endpoint().clone();
    let mut hist: Option<RunHistory> = None;
    std::thread::scope(|s| {
        let handle = s.spawn(|| coordinator.serve(&run, workers, init, &|p| e.evaluate(p)));

        // The hostile client claims worker 2 with a well-formed
        // rendezvous; an honest raw client covers the rest.
        let mut evil = RawWire::connect(&ep);
        let mut honest = RawWire::connect(&ep);
        evil.join(2, 3, cfg);
        honest.join(0, 2, cfg);

        let (et, esel) = evil.expect_round();
        assert_eq!(esel, vec![2]);
        evil.send_update(et, 2, d + 3); // dimension lie
        // The server's answer to a payload violation is a shutdown: the
        // next read hits EOF, not a typed reject.
        assert!(evil.recv().is_none(), "garbage sender must be hung up on");

        for _ in 0..rounds {
            let (t, sel) = honest.expect_round();
            for &w in &sel {
                honest.send_update(t, w, d);
            }
        }
        assert!(matches!(honest.recv(), Some(Msg::Fin { .. })), "expected Fin");
        hist = Some(handle.join().expect("server thread").expect("serve"));
    });
    let hist = hist.unwrap();

    assert_eq!(hist.reports.len(), rounds);
    assert!(hist.final_params.iter().all(|v| v.is_finite()));
    // The hostile worker's slot went unfilled in both rounds; its frames
    // never became rejects (the violation is below the reject layer).
    assert_eq!(hist.ledger.total_stragglers(), rounds);
    assert_eq!(hist.ledger.total_rejects(), 0);
    assert_eq!(*hist.ledger.rejects_by_kind(), [0u64; 6]);
}
