//! Sharded aggregation tree equivalence (DESIGN.md §14): routing a
//! federated run through aggregator shards — each folding its slice of
//! the cohort into a local `VoteAccumulator` and streaming one merged
//! frame per round to the root — must produce a `RunHistory`
//! **bit-identical** to both the flat transport run and the in-process
//! engine on the same seed. Vote counts are integer sums, so the root's
//! word-parallel merge of shard counter planes commutes with folding
//! the same updates directly; these tests pin that argument end-to-end
//! over real sockets (TCP and, on unix, UDS), including partial
//! participation and a sign-flip attack cohort straddling a shard
//! boundary.
//!
//! Failure injection rides the same harness: a shard that dies
//! mid-round has its slots settled (its slice drawn as stragglers, the
//! run completing on the surviving shard), and a shard whose handshake
//! the root refuses can be respawned correctly with no trace in the
//! history.

use sparsignd::compressors::CompressorKind;
use sparsignd::coordinator::{
    chunk_bounds, AggregationRule, Algorithm, Attack, AttackPlan, ClassifierEnv, Cohort,
    GradientSource, RunHistory, TrainingRun,
};
use sparsignd::data::{DirichletPartitioner, SyntheticSpec, SyntheticTask};
use sparsignd::model::ModelKind;
use sparsignd::net::client::loopback_endpoint;
use sparsignd::net::{
    run_fleet_range, run_loopback, run_loopback_sharded, FaultPlan, FaultRole, FleetOptions,
    NetCoordinator, NetError, ServeOptions, ShardCoordinator, ShardOptions,
};
use sparsignd::optim::LrSchedule;
use sparsignd::util::rng::Pcg64;
use std::time::Duration;

fn env(workers: usize) -> ClassifierEnv {
    let task = SyntheticTask::generate(
        SyntheticSpec {
            dim: 12,
            classes: 3,
            modes: 1,
            separation: 1.8,
            noise: 0.25,
            label_noise: 0.0,
            train: 480,
            test: 120,
        },
        31,
    );
    let mut rng = Pcg64::seed_from(32);
    let fed = DirichletPartitioner { alpha: 0.5, workers }.partition(&task.train, &mut rng);
    ClassifierEnv::new(
        ModelKind::Linear { inputs: 12, classes: 3 }.build(),
        task.train,
        task.test,
        fed,
        16,
    )
}

fn base_run(alg: Algorithm, rounds: usize) -> TrainingRun {
    let mut run = TrainingRun::new(alg, LrSchedule::Const { lr: 0.05 }, rounds);
    run.eval_every = 3;
    run.seed = 11;
    run
}

/// Math-field equality — the bit-identity contract. Wire-byte tier
/// columns are *not* compared (a sharded run legitimately records
/// shard-tier traffic a flat run has no frames for).
fn assert_identical(a: &RunHistory, b: &RunHistory) {
    assert_eq!(a.final_params, b.final_params, "final params");
    assert_eq!(a.reports.len(), b.reports.len());
    for (ra, rb) in a.reports.iter().zip(&b.reports) {
        assert_eq!(ra.train_loss, rb.train_loss, "round {}", ra.round);
        assert_eq!(ra.uplink_bits, rb.uplink_bits, "round {}", ra.round);
        assert_eq!(ra.downlink_bits, rb.downlink_bits, "round {}", ra.round);
        assert_eq!(ra.cum_uplink_bits, rb.cum_uplink_bits, "round {}", ra.round);
        assert_eq!(ra.eval, rb.eval, "round {}", ra.round);
    }
    assert_eq!(a.ledger.total_uplink(), b.ledger.total_uplink());
    assert_eq!(a.ledger.total_downlink(), b.ledger.total_downlink());
    assert_eq!(a.ledger.total_uplink_nnz(), b.ledger.total_uplink_nnz());
}

/// In-process, flat-transport, and sharded-transport runs of the same
/// config; pins all three identical and returns the sharded history.
fn sharded_vs_flat_vs_in_process(
    run: &TrainingRun,
    workers: usize,
    shards: usize,
    uds: bool,
) -> RunHistory {
    let e = env(workers);
    let mut rng = Pcg64::seed_from(33);
    let init = e.init_params(&mut rng);
    let in_process = run.run(&e, init.clone(), &|p| e.evaluate(p));

    let eval = |p: &[f32]| e.evaluate(p);
    let fleet_opts = FleetOptions { agents: 2, ..FleetOptions::default() };
    let (flat_hist, _) = run_loopback(
        run,
        &e,
        init.clone(),
        &eval,
        ServeOptions::new(loopback_endpoint(uds)),
        &fleet_opts,
    )
    .expect("flat loopback run");
    assert_identical(&in_process, &flat_hist);
    // Flat runs have no shard tier to account for.
    assert_eq!(flat_hist.ledger.total_shard_uplink_wire_bytes(), 0);
    assert_eq!(flat_hist.ledger.total_shard_downlink_wire_bytes(), 0);

    let (shard_hist, stats, shard_stats) = run_loopback_sharded(
        run,
        &e,
        init,
        &eval,
        ServeOptions::new(loopback_endpoint(uds)),
        &fleet_opts,
        shards,
        uds,
    )
    .expect("sharded loopback run");
    assert_identical(&in_process, &shard_hist);

    // The tree really carried the rounds: every shard relayed every
    // round and the root's ledger saw shard-tier frames both ways.
    assert_eq!(shard_stats.len(), shards);
    let folded: u64 = shard_stats.iter().map(|s| s.updates_folded).sum();
    let senders: u64 = (0..shard_hist.ledger.rounds())
        .map(|t| shard_hist.ledger.get(t).unwrap().senders as u64)
        .sum();
    assert_eq!(folded, senders, "every accepted update folded at exactly one shard");
    for (i, s) in shard_stats.iter().enumerate() {
        assert!(s.rounds_relayed >= run.rounds as u64, "shard {i} relayed too few rounds");
        assert_eq!(s.rejects_from_root, 0, "shard {i} drew rejects from the root");
        assert!(s.root_up_bytes > 0 && s.root_down_bytes > 0, "shard {i} tier bytes");
    }
    assert!(shard_hist.ledger.total_shard_uplink_wire_bytes() > 0);
    assert!(shard_hist.ledger.total_shard_downlink_wire_bytes() > 0);
    assert_eq!(shard_hist.ledger.total_stragglers(), 0);
    assert_eq!(stats.rejected, 0);
    shard_hist
}

#[test]
fn sharded_tree_matches_flat_and_in_process_over_tcp() {
    let run = base_run(
        Algorithm::CompressedGd {
            compressor: CompressorKind::Sparsign { budget: 0.7 },
            aggregation: AggregationRule::MajorityVote,
        },
        6,
    );
    // 10 workers over 3 shards: uneven ranges (4/3/3) cross-check the
    // covered-range bookkeeping.
    sharded_vs_flat_vs_in_process(&run, 10, 3, false);
}

#[cfg(unix)]
#[test]
fn sharded_tree_matches_flat_and_in_process_over_uds() {
    let run = base_run(
        Algorithm::CompressedGd {
            compressor: CompressorKind::Sign,
            aggregation: AggregationRule::ScaledSign,
        },
        6,
    );
    sharded_vs_flat_vs_in_process(&run, 9, 2, true);
}

#[test]
fn sharded_partial_participation_selection_stays_at_the_root() {
    let mut run = base_run(
        Algorithm::CompressedGd {
            compressor: CompressorKind::Sign,
            aggregation: AggregationRule::MajorityVote,
        },
        8,
    );
    run.participation = 0.5;
    let hist = sharded_vs_flat_vs_in_process(&run, 10, 2, false);
    for t in 0..hist.ledger.rounds() {
        assert_eq!(hist.ledger.get(t).unwrap().senders, 5, "round {t}");
    }
}

#[test]
fn sign_flip_cohort_split_across_shards_matches_in_process() {
    // Gradient-level attacks run identically in-process and on the wire;
    // the cohort 3..7 straddles the 2-shard boundary at worker 5, so
    // both shards fold attacked and honest votes into the same merge.
    let mut run = base_run(
        Algorithm::CompressedGd {
            compressor: CompressorKind::Sign,
            aggregation: AggregationRule::MajorityVote,
        },
        6,
    );
    run.attack =
        Some(AttackPlan::composed(vec![Cohort::explicit(Attack::SignFlip, vec![3, 4, 5, 6], 1)]));
    sharded_vs_flat_vs_in_process(&run, 10, 2, false);
}

/// A shard that claims its range and then dies mid-round (its own
/// downstream fleet never arrives, so its rendezvous bound trips while
/// the root's round is open). The root settles the dead shard's slots
/// immediately — its slice is drawn as stragglers — and completes every
/// round on the surviving shard alone.
#[test]
fn shard_death_mid_round_settles_and_the_run_completes() {
    let workers = 8;
    let rounds = 4;
    let e = env(workers);
    let run = base_run(
        Algorithm::CompressedGd {
            compressor: CompressorKind::Sign,
            aggregation: AggregationRule::MajorityVote,
        },
        rounds,
    );
    let mut rng = Pcg64::seed_from(33);
    let init = e.init_params(&mut rng);

    let coordinator =
        NetCoordinator::bind(ServeOptions::new(loopback_endpoint(false))).expect("root bind");
    let root_ep = coordinator.local_endpoint().clone();
    let mid = workers / 2;
    let live = ShardCoordinator::bind(ShardOptions::new(
        root_ep.clone(),
        loopback_endpoint(false),
        0,
        mid,
    ))
    .expect("live shard bind");
    let live_ep = live.local_endpoint().clone();
    let mut doomed_opts =
        ShardOptions::new(root_ep.clone(), loopback_endpoint(false), mid, workers);
    // No fleet will ever dial this shard; a short rendezvous bound makes
    // it die while the root's round 0 is collecting.
    doomed_opts.rendezvous_timeout = Duration::from_millis(300);
    let doomed = ShardCoordinator::bind(doomed_opts).expect("doomed shard bind");

    let fleet_opts = FleetOptions { agents: 1, ..FleetOptions::default() };
    let eval = |p: &[f32]| e.evaluate(p);
    let (root_out, live_out, doomed_out, fleet_out) = std::thread::scope(|s| {
        let root = s.spawn(|| coordinator.serve(&run, workers, init, &eval));
        let live_h = s.spawn(|| live.run(&run, workers, e.dim()));
        let doomed_h = s.spawn(|| doomed.run(&run, workers, e.dim()));
        let fleet_h = s.spawn(|| run_fleet_range(&live_ep, &run, &e, 0, mid, &fleet_opts));
        (
            root.join().expect("root thread"),
            live_h.join().expect("live shard thread"),
            doomed_h.join().expect("doomed shard thread"),
            fleet_h.join().expect("fleet thread"),
        )
    });

    let err = doomed_out.expect_err("the doomed shard must die uncovered");
    assert!(
        matches!(&err, NetError::Protocol(s) if s.contains("never covered")),
        "unexpected doomed-shard exit: {err}"
    );
    let hist = root_out.expect("root must complete despite the dead shard");
    let live_stats = live_out.expect("surviving shard must complete");
    fleet_out.expect("surviving fleet must complete");

    assert_eq!(hist.ledger.rounds(), rounds);
    for t in 0..rounds {
        let rc = hist.ledger.get(t).unwrap();
        // Only the surviving shard's slice ever submits; the dead
        // shard's workers are stragglers every round.
        assert_eq!(rc.senders, mid, "round {t} senders");
        assert_eq!(rc.stragglers, workers - mid, "round {t} stragglers");
    }
    assert_eq!(live_stats.updates_folded, (mid * rounds) as u64);
}

/// A shard the root refuses at handshake (wrong environment
/// fingerprint) is indistinguishable from one that never dialed: the
/// root keeps waiting out its rendezvous window, a correctly-configured
/// replacement re-claims the same range, and the completed run is
/// bit-identical to the in-process engine.
#[test]
fn refused_shard_respawn_reclaims_and_stays_bit_identical() {
    let workers = 8;
    let e = env(workers);
    let run = base_run(
        Algorithm::CompressedGd {
            compressor: CompressorKind::Sparsign { budget: 0.7 },
            aggregation: AggregationRule::MajorityVote,
        },
        4,
    );
    let mut rng = Pcg64::seed_from(33);
    let init = e.init_params(&mut rng);
    let in_process = run.run(&e, init.clone(), &|p| e.evaluate(p));
    let env_fp = e.env_fingerprint();

    let mut serve_opts = ServeOptions::new(loopback_endpoint(false));
    serve_opts.env_fingerprint = env_fp;
    serve_opts.rendezvous_timeout = Duration::from_secs(20);
    let coordinator = NetCoordinator::bind(serve_opts).expect("root bind");
    let root_ep = coordinator.local_endpoint().clone();
    let mid = workers / 2;
    let shard_opts = |lo: usize, hi: usize| {
        let mut so = ShardOptions::new(root_ep.clone(), loopback_endpoint(false), lo, hi);
        so.env_fingerprint = env_fp;
        so
    };
    let good_a = ShardCoordinator::bind(shard_opts(0, mid)).expect("shard a bind");
    let a_ep = good_a.local_endpoint().clone();
    let mut bad_opts = shard_opts(mid, workers);
    bad_opts.env_fingerprint = 0xdead_beef; // refused by the armed root
    let bad = ShardCoordinator::bind(bad_opts).expect("bad shard bind");

    let fleet_opts = FleetOptions { agents: 1, ..FleetOptions::default() };
    let eval = |p: &[f32]| e.evaluate(p);
    let (root_out, fleet_a, fleet_b) = std::thread::scope(|s| {
        let root = s.spawn(|| coordinator.serve(&run, workers, init, &eval));
        let a_h = s.spawn(|| good_a.run(&run, workers, e.dim()));
        let fa = s.spawn(|| run_fleet_range(&a_ep, &run, &e, 0, mid, &fleet_opts));

        // The refused shard never claims: the root hangs up on its
        // ShardHello before any Welcome.
        let bad_err = bad.run(&run, workers, e.dim()).expect_err("bad shard must be refused");
        assert!(
            matches!(bad_err, NetError::Disconnected | NetError::Io(_) | NetError::Protocol(_)),
            "unexpected refusal shape: {bad_err}"
        );

        // Respawn with the right fingerprint; the range is still free,
        // the root is still in rendezvous, and the run proceeds whole.
        let good_b =
            ShardCoordinator::bind(shard_opts(mid, workers)).expect("shard b bind");
        let b_ep = good_b.local_endpoint().clone();
        let b_h = s.spawn(|| good_b.run(&run, workers, e.dim()));
        let fb = s.spawn(|| run_fleet_range(&b_ep, &run, &e, mid, workers, &fleet_opts));

        let root_out = root.join().expect("root thread");
        a_h.join().expect("shard a thread").expect("shard a run");
        b_h.join().expect("shard b thread").expect("shard b run");
        (
            root_out,
            fa.join().expect("fleet a thread"),
            fb.join().expect("fleet b thread"),
        )
    });

    let hist = root_out.expect("root run");
    assert_identical(&in_process, &hist);
    assert!(hist.ledger.total_shard_uplink_wire_bytes() > 0);
    fleet_a.expect("fleet a");
    fleet_b.expect("fleet b");
}

/// Strict self-healing (`heal_attempts`): a round that closes below
/// full coverage is re-opened, and a shard respawned into the freed
/// range re-covers it, so the completed run is **bit-identical** to the
/// in-process engine — the churn-soak contract, in-process. The doomed
/// shard claims its range and dies during the run (its own rendezvous
/// bound trips); under the legacy policy its slice would be stragglers
/// forever, under strict healing the root parks the short round until
/// the replacement re-claims.
#[test]
fn strict_healing_reopens_short_rounds_until_a_respawned_shard_recovers() {
    let workers = 8;
    let rounds = 4;
    let e = env(workers);
    let run = base_run(
        Algorithm::CompressedGd {
            compressor: CompressorKind::Sparsign { budget: 0.7 },
            aggregation: AggregationRule::MajorityVote,
        },
        rounds,
    );
    let mut rng = Pcg64::seed_from(33);
    let init = e.init_params(&mut rng);
    let in_process = run.run(&e, init.clone(), &|p| e.evaluate(p));

    let mut serve_opts = ServeOptions::new(loopback_endpoint(false));
    serve_opts.rendezvous_timeout = Duration::from_secs(30);
    serve_opts.heal_attempts = Some(4);
    let coordinator = NetCoordinator::bind(serve_opts).expect("root bind");
    let root_ep = coordinator.local_endpoint().clone();
    let mid = workers / 2;
    let live = ShardCoordinator::bind(ShardOptions::new(
        root_ep.clone(),
        loopback_endpoint(false),
        0,
        mid,
    ))
    .expect("live shard bind");
    let live_ep = live.local_endpoint().clone();
    let mut doomed_opts =
        ShardOptions::new(root_ep.clone(), loopback_endpoint(false), mid, workers);
    doomed_opts.rendezvous_timeout = Duration::from_millis(300);
    let doomed = ShardCoordinator::bind(doomed_opts).expect("doomed shard bind");

    let fleet_opts = FleetOptions { agents: 1, ..FleetOptions::default() };
    let eval = |p: &[f32]| e.evaluate(p);
    let (root_out, fleet_a, fleet_b) = std::thread::scope(|s| {
        let root = s.spawn(|| coordinator.serve(&run, workers, init, &eval));
        let live_h = s.spawn(|| live.run(&run, workers, e.dim()));
        let doomed_h = s.spawn(|| doomed.run(&run, workers, e.dim()));
        let fa = s.spawn(|| run_fleet_range(&live_ep, &run, &e, 0, mid, &fleet_opts));

        // Let the doomed shard die first (rendezvous bound 300ms), then
        // respawn its range; the root is parked on the short round.
        std::thread::sleep(Duration::from_millis(1_000));
        let doomed_err =
            doomed_h.join().expect("doomed thread").expect_err("doomed shard must die");
        assert!(
            matches!(&doomed_err, NetError::Protocol(s) if s.contains("never covered")),
            "unexpected doomed-shard exit: {doomed_err}"
        );
        let respawn = ShardCoordinator::bind(ShardOptions::new(
            root_ep.clone(),
            loopback_endpoint(false),
            mid,
            workers,
        ))
        .expect("respawn bind");
        let respawn_ep = respawn.local_endpoint().clone();
        let r_h = s.spawn(|| respawn.run(&run, workers, e.dim()));
        let fb = s.spawn(|| run_fleet_range(&respawn_ep, &run, &e, mid, workers, &fleet_opts));

        let root_out = root.join().expect("root thread");
        live_h.join().expect("live thread").expect("live shard run");
        r_h.join().expect("respawn thread").expect("respawned shard run");
        (root_out, fa.join().expect("fleet a"), fb.join().expect("fleet b"))
    });

    let hist = root_out.expect("root must heal to completion");
    assert_identical(&in_process, &hist);
    assert_eq!(hist.ledger.total_rejects(), 0);
    for t in 0..rounds {
        let rc = hist.ledger.get(t).unwrap();
        assert_eq!(rc.senders, workers, "round {t} must close fully covered");
        assert_eq!(rc.stragglers, 0, "round {t} stragglers");
    }
    fleet_a.expect("fleet a stats");
    fleet_b.expect("fleet b stats");
}

/// A `partition:shard:round=2` fault: the shard severs its own upstream
/// at the open of round 2 and takes the reconnect path — epoch-fencing
/// its downstream sessions so no in-flight update of the voided round
/// can land as a reject after the re-open. The root heals the short
/// round, the fleet re-claims through the fence, and the completed run
/// stays bit-identical with zero rejects anywhere.
#[test]
fn partitioned_shard_reconnects_fences_downstream_and_stays_bit_identical() {
    let workers = 8;
    let rounds = 5;
    let e = env(workers);
    let run = base_run(
        Algorithm::CompressedGd {
            compressor: CompressorKind::Sign,
            aggregation: AggregationRule::MajorityVote,
        },
        rounds,
    );
    let mut rng = Pcg64::seed_from(33);
    let init = e.init_params(&mut rng);
    let in_process = run.run(&e, init.clone(), &|p| e.evaluate(p));
    let plan = FaultPlan::parse("partition:shard:round=2", 7).expect("fault plan");

    let mut serve_opts = ServeOptions::new(loopback_endpoint(false));
    serve_opts.rendezvous_timeout = Duration::from_secs(30);
    serve_opts.heal_attempts = Some(4);
    let coordinator = NetCoordinator::bind(serve_opts).expect("root bind");
    let root_ep = coordinator.local_endpoint().clone();
    let mid = workers / 2;
    let steady = ShardCoordinator::bind(ShardOptions::new(
        root_ep.clone(),
        loopback_endpoint(false),
        0,
        mid,
    ))
    .expect("steady shard bind");
    let steady_ep = steady.local_endpoint().clone();
    let mut flaky_opts =
        ShardOptions::new(root_ep.clone(), loopback_endpoint(false), mid, workers);
    flaky_opts.reconnect = Some(Duration::from_secs(20));
    flaky_opts.faults = Some(plan.injector(FaultRole::Shard));
    let flaky = ShardCoordinator::bind(flaky_opts).expect("flaky shard bind");
    let flaky_ep = flaky.local_endpoint().clone();

    let steady_fleet = FleetOptions { agents: 1, ..FleetOptions::default() };
    // The fenced fleet must survive its sessions being dropped by the
    // reconnecting shard (Sign is stateless, so replay is sound).
    let fenced_fleet = FleetOptions {
        agents: 1,
        reconnect: Some(Duration::from_secs(20)),
        ..FleetOptions::default()
    };
    let eval = |p: &[f32]| e.evaluate(p);
    let (root_out, flaky_out, fenced_out) = std::thread::scope(|s| {
        let root = s.spawn(|| coordinator.serve(&run, workers, init, &eval));
        let steady_h = s.spawn(|| steady.run(&run, workers, e.dim()));
        let flaky_h = s.spawn(|| flaky.run(&run, workers, e.dim()));
        let fa = s.spawn(|| run_fleet_range(&steady_ep, &run, &e, 0, mid, &steady_fleet));
        let fb = s.spawn(|| run_fleet_range(&flaky_ep, &run, &e, mid, workers, &fenced_fleet));
        let root_out = root.join().expect("root thread");
        steady_h.join().expect("steady thread").expect("steady shard run");
        let flaky_out = flaky_h.join().expect("flaky thread").expect("flaky shard run");
        fa.join().expect("steady fleet").expect("steady fleet stats");
        (root_out, flaky_out, fb.join().expect("fenced fleet"))
    });

    let hist = root_out.expect("root must heal the partitioned round");
    assert_identical(&in_process, &hist);
    assert_eq!(hist.ledger.total_rejects(), 0, "the fence must prevent every reject");
    for t in 0..rounds {
        let rc = hist.ledger.get(t).unwrap();
        assert_eq!(rc.senders, workers, "round {t} must close fully covered");
        assert_eq!(rc.stragglers, 0, "round {t} stragglers");
    }
    assert_eq!(flaky_out.upstream_reconnects, 1, "exactly one scheduled partition");
    let fenced_stats = fenced_out.expect("fenced fleet stats");
    assert!(
        fenced_stats.reconnects >= 1,
        "the fence must have dropped (and recovered) the downstream session"
    );
}

/// `chunk_bounds` is the contract both sides of the tree share: the
/// serving side claims it, `fleet --via-shards` dials by it. Pin the
/// partition law the docs promise (disjoint, covering, ±1 balanced).
#[test]
fn shard_ranges_partition_the_population() {
    for (m, shards) in [(10usize, 3usize), (8, 2), (100_000, 4), (7, 7)] {
        let mut next = 0;
        for i in 0..shards {
            let (lo, hi) = chunk_bounds(m, shards, i);
            assert_eq!(lo, next, "m={m} shards={shards} i={i}");
            assert!(hi > lo, "empty shard range m={m} shards={shards} i={i}");
            next = hi;
        }
        assert_eq!(next, m, "m={m} shards={shards} must cover the population");
    }
}
