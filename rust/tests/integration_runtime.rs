//! Cross-language integration: the AOT-compiled JAX artifacts executed
//! through PJRT must agree with the pure-rust implementations — the same
//! model, same flat parameter layout, two independent stacks.
//!
//! Requires `make artifacts` (skips gracefully when absent so `cargo test`
//! works on a fresh checkout).

use sparsignd::model::{Mlp, Model};
use sparsignd::runtime::{literal_f32, literal_u32, scalar_f32, vec_f32, HloModel, Runtime};
use sparsignd::util::rng::Pcg64;

fn runtime() -> Option<std::rc::Rc<Runtime>> {
    match Runtime::cpu("artifacts") {
        Ok(rt) => Some(std::rc::Rc::new(rt)),
        Err(e) => {
            eprintln!("skipping runtime integration test: {e}");
            None
        }
    }
}

#[test]
fn hlo_mlp_grad_matches_pure_rust() {
    let Some(rt) = runtime() else { return };
    let hlo = HloModel::load(rt, "mlp_small", 32, vec![32], 5).expect("load mlp_small");
    let rust = Mlp::new(32, vec![32], 5);
    assert_eq!(hlo.dim(), rust.dim());
    let batch = hlo.batch();

    let mut rng = Pcg64::seed_from(1);
    let params = rust.init(&mut rng);
    let mut x = vec![0.0f32; batch * 32];
    rng.fill_normal(&mut x, 0.0, 1.0);
    let y: Vec<usize> = (0..batch).map(|_| rng.index(5)).collect();

    let mut g_hlo = vec![0.0f32; hlo.dim()];
    let mut g_rust = vec![0.0f32; rust.dim()];
    let l_hlo = hlo.loss_grad(&params, &x, &y, &mut g_hlo);
    let l_rust = rust.loss_grad(&params, &x, &y, &mut g_rust);

    assert!(
        (l_hlo - l_rust).abs() < 1e-4,
        "loss mismatch: hlo {l_hlo} vs rust {l_rust}"
    );
    let mut max_rel = 0.0f32;
    for (i, (a, b)) in g_hlo.iter().zip(&g_rust).enumerate() {
        let rel = (a - b).abs() / a.abs().max(b.abs()).max(1e-3);
        if rel > max_rel {
            max_rel = rel;
        }
        assert!(rel < 5e-3, "grad coord {i}: hlo {a} vs rust {b}");
    }
    println!("max relative grad deviation: {max_rel:.2e}");
}

#[test]
fn hlo_mlp_evaluate_matches_pure_rust() {
    let Some(rt) = runtime() else { return };
    let hlo = HloModel::load(rt, "mlp_small", 32, vec![32], 5).expect("load");
    let rust = Mlp::new(32, vec![32], 5);
    let mut rng = Pcg64::seed_from(2);
    let params = rust.init(&mut rng);
    // Odd-sized eval set exercises the padded-chunk path.
    let n = 150;
    let mut x = vec![0.0f32; n * 32];
    rng.fill_normal(&mut x, 0.0, 1.0);
    let y: Vec<usize> = (0..n).map(|_| rng.index(5)).collect();
    let (l1, a1) = hlo.evaluate(&params, &x, &y);
    let (l2, a2) = rust.evaluate(&params, &x, &y);
    assert!((l1 - l2).abs() < 1e-4, "{l1} vs {l2}");
    assert!((a1 - a2).abs() < 1e-9, "{a1} vs {a2}");
}

#[test]
fn fused_sparsign_artifact_produces_valid_ternary_codes() {
    let Some(rt) = runtime() else { return };
    let spec = match rt.registry().spec("mlp_fmnist_grad_sparsign_b1") {
        Ok(s) => s.inputs.clone(),
        Err(_) => return,
    };
    let dim = spec[0].dims[0] as usize;
    let batch = spec[1].dims[0] as usize;
    let feat = spec[1].dims[1] as usize;
    let classes = spec[2].dims[1] as usize;
    let mut rng = Pcg64::seed_from(3);
    let mut params = vec![0.0f32; dim];
    rng.fill_normal(&mut params, 0.0, 0.05);
    let mut x = vec![0.0f32; batch * feat];
    rng.fill_normal(&mut x, 0.0, 1.0);
    let mut y = vec![0.0f32; batch * classes];
    for i in 0..batch {
        y[i * classes + rng.index(classes)] = 1.0;
    }
    let inputs = vec![
        literal_f32(&params, &[dim as i64]).unwrap(),
        literal_f32(&x, &[batch as i64, feat as i64]).unwrap(),
        literal_f32(&y, &[batch as i64, classes as i64]).unwrap(),
        literal_u32(&[7, 11], &[2]).unwrap(),
    ];
    let out = rt.execute("mlp_fmnist_grad_sparsign_b1", &inputs).unwrap();
    let loss = scalar_f32(&out[0]).unwrap();
    let codes = vec_f32(&out[1]).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert_eq!(codes.len(), dim);
    // L1 Pallas output contract: ternary, sign-consistent with the raw
    // gradient from the unfused artifact.
    let raw = rt.execute("mlp_fmnist_grad", &inputs[..3]).unwrap();
    let grad = vec_f32(&raw[1]).unwrap();
    let mut nnz = 0usize;
    for (i, (&c, &g)) in codes.iter().zip(&grad).enumerate() {
        assert!(c == 0.0 || c == 1.0 || c == -1.0, "coord {i}: code {c}");
        if c != 0.0 {
            nnz += 1;
            assert!(c * g > 0.0, "coord {i}: code {c} vs grad {g}");
        }
    }
    // Same key ⇒ identical codes (stateless threefry contract).
    let out2 = rt.execute("mlp_fmnist_grad_sparsign_b1", &inputs).unwrap();
    assert_eq!(vec_f32(&out2[1]).unwrap(), codes);
    // Density sanity: E[nnz] = Σ min(1, |g|) for B = 1.
    let expect: f64 = grad.iter().map(|g| (g.abs() as f64).min(1.0)).sum();
    let got = nnz as f64;
    assert!(
        (got - expect).abs() < 6.0 * expect.sqrt().max(10.0),
        "nnz {got} vs E[nnz] {expect:.1}"
    );
}

#[test]
fn rosenbrock_artifact_matches_rust() {
    let Some(rt) = runtime() else { return };
    if rt.registry().spec("rosenbrock_grad").is_err() {
        return;
    }
    let f = sparsignd::model::rosenbrock::Rosenbrock::new(10);
    let mut rng = Pcg64::seed_from(4);
    let mut x = vec![0.0f32; 10];
    rng.fill_normal(&mut x, 0.0, 0.5);
    let out = rt
        .execute("rosenbrock_grad", &[literal_f32(&x, &[10]).unwrap()])
        .unwrap();
    let val = scalar_f32(&out[0]).unwrap() as f64;
    let grad = vec_f32(&out[1]).unwrap();
    assert!((val - f.value(&x)).abs() / f.value(&x).max(1.0) < 1e-4);
    let mut g = vec![0.0f32; 10];
    f.grad(&x, &mut g);
    for (a, b) in grad.iter().zip(&g) {
        assert!((a - b).abs() / b.abs().max(1.0) < 1e-3, "{a} vs {b}");
    }
}

#[test]
fn transformer_artifacts_roundtrip() {
    let Some(rt) = runtime() else { return };
    if rt.registry().spec("transformer_init").is_err() {
        return;
    }
    let init = rt
        .execute("transformer_init", &[literal_u32(&[1, 2], &[2]).unwrap()])
        .unwrap();
    let params = vec_f32(&init[0]).unwrap();
    assert!(params.iter().all(|v| v.is_finite()));
    // LayerNorm gains initialized to 1 somewhere in the vector.
    assert!(params.iter().filter(|&&v| v == 1.0).count() > 100);
    let tok: Vec<i32> = (0..8 * 32).map(|i| (i % 64) as i32).collect();
    let out = rt
        .execute(
            "transformer_grad",
            &[
                literal_f32(&params, &[params.len() as i64]).unwrap(),
                sparsignd::runtime::literal_i32(&tok, &[8, 32]).unwrap(),
                sparsignd::runtime::literal_i32(&tok, &[8, 32]).unwrap(),
            ],
        )
        .unwrap();
    let loss = scalar_f32(&out[0]).unwrap();
    assert!(loss.is_finite() && loss > 0.0 && loss < 10.0);
    assert_eq!(vec_f32(&out[1]).unwrap().len(), params.len());
}

#[test]
fn registry_rejects_wrong_shapes() {
    let Some(rt) = runtime() else { return };
    if rt.registry().spec("rosenbrock_grad").is_err() {
        return;
    }
    // Wrong input count.
    assert!(rt.execute("rosenbrock_grad", &[]).is_err());
    // Wrong element count.
    let bad = literal_f32(&[1.0; 4], &[4]).unwrap();
    assert!(rt.execute("rosenbrock_grad", &[bad]).is_err());
    // Unknown artifact.
    assert!(rt.executable("nonexistent_model").is_err());
}
