//! Streaming data plane end-to-end (DESIGN.md §16): an `.sgds` store fed
//! to both sides of a loopback federation must reproduce the in-process
//! engine bit-identically (the acceptance contract behind `fleet
//! --data`), and a fleet built from a drifted store must be refused at
//! rendezvous by the coordinator's environment fingerprint check.

use sparsignd::compressors::CompressorKind;
use sparsignd::coordinator::{
    AggregationRule, Algorithm, ClassifierEnv, GradientSource, RunHistory, TrainingRun,
};
use sparsignd::data::{write_store, DirichletPartitioner, ShardStore, SyntheticSpec, SyntheticTask};
use sparsignd::model::ModelKind;
use sparsignd::net::client::loopback_endpoint;
use sparsignd::net::{run_loopback, FleetOptions, ServeOptions};
use sparsignd::optim::LrSchedule;
use sparsignd::util::rng::Pcg64;

fn store_path(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("sgds_stream_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{tag}.sgds"))
}

/// Write a small store whose every byte is a function of `seed` (task
/// generator and partition draw both derive from it).
fn build_store(tag: &str, seed: u64) -> std::path::PathBuf {
    let task = SyntheticTask::generate(
        SyntheticSpec { train: 360, test: 90, ..SyntheticSpec::fmnist_like().with_dim(10) },
        seed,
    );
    let fed = DirichletPartitioner { alpha: 0.5, workers: 9 }
        .partition_exact(&task.train, &mut Pcg64::seed_from(seed ^ 0x9a57));
    let path = store_path(tag);
    write_store(&path, &task.train, &task.test, &fed, 0.5, seed).unwrap();
    path
}

fn env_from(path: &std::path::Path, batch: usize) -> ClassifierEnv {
    let store = ShardStore::open(path).unwrap();
    let model = ModelKind::Linear { inputs: store.dim(), classes: store.classes() }.build();
    ClassifierEnv::from_store(&store, model, batch)
}

fn assert_identical(a: &RunHistory, b: &RunHistory) {
    assert_eq!(a.final_params, b.final_params, "final params");
    assert_eq!(a.reports.len(), b.reports.len());
    for (ra, rb) in a.reports.iter().zip(&b.reports) {
        assert_eq!(ra.train_loss, rb.train_loss, "round {}", ra.round);
        assert_eq!(ra.uplink_bits, rb.uplink_bits, "round {}", ra.round);
        assert_eq!(ra.eval, rb.eval, "round {}", ra.round);
    }
    assert_eq!(a.ledger.total_uplink(), b.ledger.total_uplink());
    assert_eq!(a.ledger.total_uplink_nnz(), b.ledger.total_uplink_nnz());
}

#[test]
fn store_backed_loopback_matches_in_process_engine() {
    let path = build_store("identity", 41);
    let env = env_from(&path, 12);
    // The store's feature matrix streams zero-copy on the platforms CI
    // runs — the loopback run below exercises the mapped read path.
    #[cfg(all(unix, target_endian = "little"))]
    assert!(matches!(env.train.x, sparsignd::data::Features::Mapped(_)));

    let mut run = TrainingRun::new(
        Algorithm::CompressedGd {
            compressor: CompressorKind::Sparsign { budget: 0.7 },
            aggregation: AggregationRule::MajorityVote,
        },
        LrSchedule::Const { lr: 0.05 },
        5,
    );
    run.eval_every = 2;
    run.seed = 11;

    let init = env.init_params(&mut Pcg64::seed_from(33));
    let in_process = run.run(&env, init.clone(), &|p| env.evaluate(p));

    // Armed environment check (as the serve CLI does) — the same store
    // on both sides must pass it and reproduce the engine bit-for-bit.
    let mut serve_opts = ServeOptions::new(loopback_endpoint(cfg!(unix)));
    serve_opts.env_fingerprint = env.env_fingerprint();
    let fleet_opts = FleetOptions { agents: 3, ..FleetOptions::default() };
    let eval = |p: &[f32]| env.evaluate(p);
    let (wire_hist, stats) =
        run_loopback(&run, &env, init, &eval, serve_opts, &fleet_opts).expect("loopback run");
    assert_eq!(stats.rejected, 0);
    assert!(stats.updates_sent > 0);
    assert_identical(&in_process, &wire_hist);

    let _ = std::fs::remove_file(&path);
}

#[test]
fn drifted_store_changes_fingerprint_and_is_refused_at_rendezvous() {
    let path_a = build_store("drift_a", 41);
    let path_b = build_store("drift_b", 42);
    let env_a = env_from(&path_a, 12);
    let env_b = env_from(&path_b, 12);
    // Identical shapes — only the sampled bytes and the embedded
    // manifest differ, exactly the drift a run config cannot see.
    assert_eq!(env_a.dim(), env_b.dim());
    assert_eq!(env_a.workers(), env_b.workers());
    assert_ne!(env_a.env_fingerprint(), env_b.env_fingerprint());
    // Reloading the same file is stable; a batch change alone moves it.
    assert_eq!(env_a.env_fingerprint(), env_from(&path_a, 12).env_fingerprint());
    assert_ne!(env_a.env_fingerprint(), env_from(&path_a, 24).env_fingerprint());

    // End-to-end: a coordinator armed with store A's environment hash
    // hangs up on a fleet built from store B, and the run dies at
    // rendezvous instead of silently diverging.
    let mut run = TrainingRun::new(
        Algorithm::CompressedGd {
            compressor: CompressorKind::Sign,
            aggregation: AggregationRule::MajorityVote,
        },
        LrSchedule::Const { lr: 0.05 },
        2,
    );
    run.seed = 11;
    let init = env_b.init_params(&mut Pcg64::seed_from(33));
    let mut serve_opts = ServeOptions::new(loopback_endpoint(cfg!(unix)));
    serve_opts.env_fingerprint = env_a.env_fingerprint();
    serve_opts.rendezvous_timeout = std::time::Duration::from_millis(1500);
    let fleet_opts = FleetOptions { agents: 2, ..FleetOptions::default() };
    let eval = |p: &[f32]| env_b.evaluate(p);
    let out = run_loopback(&run, &env_b, init, &eval, serve_opts, &fleet_opts);
    assert!(out.is_err(), "drifted fleet must not complete a run");

    let _ = std::fs::remove_file(&path_a);
    let _ = std::fs::remove_file(&path_b);
}
