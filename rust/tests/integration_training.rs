//! Full-pipeline training integration: config → data → partition → train →
//! metrics, for every algorithm family, including the theory-rate
//! schedules and the paper-scale config *validation* (not execution).

use sparsignd::compressors::{CompressorKind, NormKind};
use sparsignd::config::ExperimentConfig;
use sparsignd::coordinator::{AggregationRule, Algorithm, TrainingRun};
use sparsignd::experiments::{
    build_env, run_classification, table1_config, table2_config, table3_config,
    tables4_7_configs,
};
use sparsignd::optim::LrSchedule;
use sparsignd::util::rng::Pcg64;

#[test]
fn every_algorithm_family_trains_and_accounts_bits() {
    let mut cfg = ExperimentConfig::fast_preset();
    cfg.rounds = 25;
    let env = build_env(&cfg, 0xda7a);
    let mut init_rng = Pcg64::new(0, 0x1217);
    let init = env.init_params(&mut init_rng);
    let algorithms = vec![
        Algorithm::CompressedGd {
            compressor: CompressorKind::Sign,
            aggregation: AggregationRule::MajorityVote,
        },
        Algorithm::CompressedGd {
            compressor: CompressorKind::ScaledSign,
            aggregation: AggregationRule::Mean,
        },
        Algorithm::CompressedGd {
            compressor: CompressorKind::NoisySign { noise_std: 0.01 },
            aggregation: AggregationRule::MajorityVote,
        },
        Algorithm::CompressedGd {
            compressor: CompressorKind::Qsgd { levels: 1, norm: NormKind::L2 },
            aggregation: AggregationRule::Mean,
        },
        Algorithm::CompressedGd {
            compressor: CompressorKind::Qsgd { levels: 255, norm: NormKind::Linf },
            aggregation: AggregationRule::Mean,
        },
        Algorithm::CompressedGd {
            compressor: CompressorKind::TernGrad,
            aggregation: AggregationRule::Mean,
        },
        Algorithm::CompressedGd {
            compressor: CompressorKind::Sparsign { budget: 1.0 },
            aggregation: AggregationRule::MajorityVote,
        },
        Algorithm::CompressedGd {
            compressor: CompressorKind::TopK { k: 100 },
            aggregation: AggregationRule::Mean,
        },
        Algorithm::CompressedGd {
            compressor: CompressorKind::RandK { k: 100 },
            aggregation: AggregationRule::Mean,
        },
        Algorithm::CompressedGd {
            compressor: CompressorKind::ThresholdV { v: 0.001 },
            aggregation: AggregationRule::Mean,
        },
        Algorithm::CompressedGd {
            compressor: CompressorKind::Stc { k: 100 },
            aggregation: AggregationRule::Mean,
        },
        Algorithm::CompressedGd {
            compressor: CompressorKind::Identity,
            aggregation: AggregationRule::Mean,
        },
        Algorithm::EfSparsign { b_local: 10.0, b_global: 1.0, tau: 3, server_lr_scale: None, server_ef: true },
        Algorithm::FedAvg { tau: 3 },
        Algorithm::FedCom { tau: 3, levels: 255 },
    ];
    for alg in algorithms {
        let label = alg.label();
        let run = TrainingRun {
            algorithm: alg,
            schedule: LrSchedule::Const { lr: 0.01 },
            rounds: cfg.rounds,
            participation: 1.0,
            eval_every: 0,
            seed: 0,
            attack: None,
            selection: Default::default(),
            allow_stateful_with_sampling: false,
            threads: None,
        };
        let hist = run.run(&env, init.clone(), &|p| env.evaluate(p));
        assert_eq!(hist.reports.len(), cfg.rounds, "{label}");
        assert!(hist.total_uplink() > 0.0, "{label}: no uplink recorded");
        assert!(
            hist.reports.iter().all(|r| r.train_loss.is_finite()),
            "{label}: non-finite loss"
        );
        let (_, acc) = hist.final_eval().unwrap();
        assert!(acc.is_finite() && acc >= 0.0, "{label}");
        // Every round's downlink is accounted too.
        assert!(hist.reports.iter().all(|r| r.downlink_bits > 0.0), "{label}");
    }
}

#[test]
fn theory_rate_schedule_trains() {
    let mut cfg = ExperimentConfig::fast_preset();
    cfg.rounds = 200;
    let env = build_env(&cfg, 0xda7a);
    let mut init_rng = Pcg64::new(0, 0x1217);
    let init = env.init_params(&mut init_rng);
    let run = TrainingRun {
        algorithm: Algorithm::CompressedGd {
            compressor: CompressorKind::Sparsign { budget: 1.0 },
            aggregation: AggregationRule::MajorityVote,
        },
        // Theorem 2 rate: η = 1/√(T·d).
        schedule: LrSchedule::TheoryRate { total_rounds: 200, dim: env_dim(&env) },
        rounds: cfg.rounds,
        participation: 1.0,
        eval_every: 0,
        seed: 5,
        attack: None,
        selection: Default::default(),
        allow_stateful_with_sampling: false,
        threads: None,
    };
    let first_loss_run = run.run(&env, init, &|p| env.evaluate(p));
    let first = first_loss_run.reports.first().unwrap().train_loss;
    let last = first_loss_run.reports.last().unwrap().train_loss;
    assert!(last < first, "theory-rate run should reduce loss: {first} → {last}");
}

fn env_dim(env: &sparsignd::coordinator::ClassifierEnv) -> usize {
    use sparsignd::coordinator::GradientSource;
    env.dim()
}

#[test]
fn seeds_reproduce_and_differ() {
    let mut cfg = ExperimentConfig::fast_preset();
    cfg.rounds = 30;
    cfg.seeds = vec![0];
    cfg.algorithms = vec![Algorithm::CompressedGd {
        compressor: CompressorKind::Sparsign { budget: 1.0 },
        aggregation: AggregationRule::MajorityVote,
    }];
    cfg.lr_overrides.clear();
    let r1 = run_classification(&cfg);
    let r2 = run_classification(&cfg);
    assert_eq!(
        r1.summaries[0].final_acc_mean,
        r2.summaries[0].final_acc_mean,
        "same config+seed must reproduce exactly"
    );
    cfg.seeds = vec![1];
    let r3 = run_classification(&cfg);
    assert_ne!(
        r1.summaries[0].final_acc_mean, r3.summaries[0].final_acc_mean,
        "different seed should differ"
    );
}

#[test]
fn paper_scale_configs_validate_and_build_envs() {
    // We don't *run* the paper-scale configs in CI (hours of compute),
    // but they must validate and their (scaled-down) environments build.
    for cfg in [table1_config(true), table2_config(true), table3_config(true)] {
        cfg.validate().unwrap();
    }
    for cfg in tables4_7_configs(true, &[0.1, 1.0]) {
        cfg.validate().unwrap();
    }
    // Env construction sanity on a scaled-down copy of the paper config.
    let mut cfg = table1_config(true);
    cfg.data_scale = 0.02;
    let env = build_env(&cfg, 1);
    use sparsignd::coordinator::GradientSource;
    assert_eq!(env.workers(), 100);
    assert_eq!(env.dim(), 784 * 256 + 256 + 256 * 128 + 128 + 128 * 10 + 10);
}

#[test]
fn run_classification_emits_consistent_report() {
    let mut cfg = ExperimentConfig::fast_preset();
    cfg.rounds = 40;
    cfg.seeds = vec![0, 1];
    let report = run_classification(&cfg);
    // Table contains every algorithm label.
    for alg in &cfg.algorithms {
        assert!(
            report.table().contains(alg.label().split('(').next().unwrap()),
            "table missing {}",
            alg.label()
        );
    }
    // Bits-to-target ≤ total uplink; rounds ≤ configured rounds.
    for s in &report.summaries {
        for (r, b) in s.rounds_to_target.iter().zip(&s.bits_to_target) {
            if let (Some(r), Some(b)) = (r, b) {
                assert!(*r <= cfg.rounds as f64);
                assert!(*b <= s.total_uplink_mean * 1.01);
            }
        }
    }
}
