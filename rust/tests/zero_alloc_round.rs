//! Whole-round zero-allocation + zero-spawn contract for the persistent
//! pool engine (DESIGN.md §10): once the pool is constructed and the
//! first round has warmed every scratch buffer (worker scratch, packed
//! message buffers, vote accumulators, server scratch), additional
//! steady-state rounds on the packed-ternary fast path must not touch
//! the heap on ANY thread and must not spawn threads.
//!
//! Unlike `tests/zero_alloc.rs` (thread-local counter, per-component
//! contracts), the counter here is a **global atomic** so pool-thread
//! allocations count too. Measurement is differential: two runs that are
//! identical except for their round count must allocate the same number
//! of times — pool construction, warm-up growth, report/ledger
//! preallocation and the final eval all cancel, so the extra rounds must
//! contribute exactly zero allocations. A `thread::spawn` allocates
//! (stack bookkeeping, JoinHandle state), so equality also pins "zero
//! thread spawns after pool construction". This binary holds exactly one
//! test so no concurrent test can perturb the global counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use sparsignd::compressors::CompressorKind;
use sparsignd::coordinator::{AggregationRule, Algorithm, ClassifierEnv, TrainingRun};
use sparsignd::data::{DirichletPartitioner, SyntheticSpec, SyntheticTask};
use sparsignd::model::ModelKind;
use sparsignd::optim::LrSchedule;
use sparsignd::util::rng::Pcg64;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

// SAFETY: defers all allocation to `System`; the counter is a static
// atomic (no lazy init, no recursive allocation).
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc(layout) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn env() -> ClassifierEnv {
    // Same shapes `tests/zero_alloc.rs` pins allocation-free for the
    // worker-side `sample_grad_ws` path.
    let task = SyntheticTask::generate(
        SyntheticSpec {
            dim: 20,
            classes: 4,
            modes: 1,
            separation: 1.5,
            noise: 0.2,
            label_noise: 0.0,
            train: 400,
            test: 80,
        },
        7,
    );
    let mut rng = Pcg64::seed_from(8);
    let fed = DirichletPartitioner { alpha: 0.5, workers: 6 }.partition(&task.train, &mut rng);
    ClassifierEnv::new(
        ModelKind::Mlp { inputs: 20, hidden: vec![16], classes: 4 }.build(),
        task.train,
        task.test,
        fed,
        16,
    )
}

/// Run the streaming fast path (sparsign + majority vote over the pool
/// engine) for `rounds` rounds and return the allocations the whole run
/// performed across every thread.
fn run_and_count(e: &ClassifierEnv, rounds: usize) -> (Vec<f32>, u64) {
    let run = TrainingRun {
        algorithm: Algorithm::CompressedGd {
            compressor: CompressorKind::Sparsign { budget: 1.0 },
            aggregation: AggregationRule::MajorityVote,
        },
        schedule: LrSchedule::Const { lr: 0.05 },
        rounds,
        participation: 1.0,
        eval_every: 0, // eval only on the final round, once per run
        seed: 11,
        attack: None,
        selection: Default::default(),
        allow_stateful_with_sampling: false,
        threads: Some(3), // force the pool engine regardless of host cores
    };
    let mut rng = Pcg64::seed_from(12);
    let init = e.init_params(&mut rng);
    let before = ALLOCS.load(Ordering::Relaxed);
    let hist = run.run(e, init, &|p| e.evaluate(p));
    let after = ALLOCS.load(Ordering::Relaxed);
    (hist.final_params, after - before)
}

#[test]
fn pool_engine_steady_state_rounds_allocate_nothing() {
    let e = env();
    let short_rounds = 4;
    let long_rounds = 12;
    // Warm-up run first so one-time process-global initialization (lazy
    // stdlib state, allocator metadata) cannot skew the comparison.
    let _ = run_and_count(&e, short_rounds);
    let (params_short, allocs_short) = run_and_count(&e, short_rounds);
    let (params_long, allocs_long) = run_and_count(&e, long_rounds);
    // Determinism sanity: the longer run replays the shorter one's
    // prefix, so its parameters must differ only by the extra training.
    assert_eq!(params_short.len(), params_long.len());
    assert!(allocs_short > 0, "counting allocator not engaged");
    assert_eq!(
        allocs_long,
        allocs_short,
        "{} extra steady-state rounds allocated {} times (worker or server \
         side of the streaming fast path touched the heap, or the pool \
         spawned threads after construction)",
        long_rounds - short_rounds,
        allocs_long as i64 - allocs_short as i64
    );

    // The participation-1.0 identity fast path of
    // `WorkerSampler::select_into` is the selection half of the same
    // contract: once the buffer is warm it must neither draw randomness
    // nor touch the heap. (Same binary so no concurrent test can perturb
    // the global counter.)
    let sampler = sparsignd::coordinator::WorkerSampler::new(64, 1.0);
    let mut rng = Pcg64::seed_from(1);
    let raw_before = rng.to_raw();
    let mut buf = Vec::new();
    sampler.select_into(&mut rng, &mut buf); // warm the buffer
    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..32 {
        sampler.select_into(&mut rng, &mut buf);
    }
    let after = ALLOCS.load(Ordering::Relaxed);
    assert_eq!(after - before, 0, "full-participation select_into touched the heap");
    assert_eq!(rng.to_raw(), raw_before, "identity fast path must not consume randomness");
    assert_eq!(buf, (0..64).collect::<Vec<_>>());
}
