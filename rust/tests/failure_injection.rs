//! Failure injection: the engine and its substrates must fail loudly and
//! precisely on invalid configurations, and stay numerically sane on
//! degenerate-but-legal inputs.

use sparsignd::compressors::{CompressorKind, NormKind};
use sparsignd::config::ExperimentConfig;
use sparsignd::coordinator::{AggregationRule, Algorithm, ClassifierEnv, TrainingRun};
use sparsignd::data::{Dataset, DirichletPartitioner, FederatedDataset};
use sparsignd::model::ModelKind;
use sparsignd::optim::LrSchedule;
use sparsignd::util::rng::Pcg64;

fn tiny_dataset(n: usize) -> Dataset {
    let mut rng = Pcg64::seed_from(1);
    let dim = 4;
    let mut x = vec![0.0f32; n * dim];
    rng.fill_normal(&mut x, 0.0, 1.0);
    let y: Vec<usize> = (0..n).map(|i| i % 2).collect();
    Dataset { x, y, dim, classes: 2 }
}

fn tiny_env() -> ClassifierEnv {
    let data = tiny_dataset(64);
    let mut rng = Pcg64::seed_from(2);
    let fed = DirichletPartitioner { alpha: 1.0, workers: 4 }.partition(&data, &mut rng);
    ClassifierEnv::new(
        ModelKind::Linear { inputs: 4, classes: 2 }.build(),
        data.clone(),
        data,
        fed,
        8,
    )
}

fn base_run(alg: Algorithm) -> TrainingRun {
    TrainingRun {
        algorithm: alg,
        schedule: LrSchedule::Const { lr: 0.1 },
        rounds: 5,
        participation: 1.0,
        eval_every: 0,
        seed: 0,
        attack: None,
        allow_stateful_with_sampling: false,
        threads: None,
    }
}

#[test]
#[should_panic(expected = "init params dim mismatch")]
fn wrong_init_dim_rejected() {
    let env = tiny_env();
    let run = base_run(Algorithm::CompressedGd {
        compressor: CompressorKind::Sign,
        aggregation: AggregationRule::MajorityVote,
    });
    run.run(&env, vec![0.0; 3], &|p| env.evaluate(p));
}

#[test]
#[should_panic(expected = "at least one round")]
fn zero_rounds_rejected() {
    let env = tiny_env();
    let mut run = base_run(Algorithm::FedAvg { tau: 1 });
    run.rounds = 0;
    let mut rng = Pcg64::seed_from(3);
    let init = env.init_params(&mut rng);
    run.run(&env, init, &|p| env.evaluate(p));
}

#[test]
#[should_panic(expected = "participation must be in")]
fn bad_participation_rejected() {
    let env = tiny_env();
    let mut run = base_run(Algorithm::FedAvg { tau: 1 });
    run.participation = 1.5;
    let mut rng = Pcg64::seed_from(4);
    let init = env.init_params(&mut rng);
    run.run(&env, init, &|p| env.evaluate(p));
}

#[test]
#[should_panic(expected = "worker-side state")]
fn stale_ef_configuration_rejected_by_default() {
    let env = tiny_env();
    let mut run = base_run(Algorithm::CompressedGd {
        compressor: CompressorKind::WorkerEf(Box::new(CompressorKind::Sign)),
        aggregation: AggregationRule::MajorityVote,
    });
    run.participation = 0.5;
    let mut rng = Pcg64::seed_from(5);
    let init = env.init_params(&mut rng);
    run.run(&env, init, &|p| env.evaluate(p));
}

#[test]
fn stale_ef_override_runs_but_is_explicit() {
    let env = tiny_env();
    let mut run = base_run(Algorithm::CompressedGd {
        compressor: CompressorKind::WorkerEf(Box::new(CompressorKind::ScaledSign)),
        aggregation: AggregationRule::Mean,
    });
    run.participation = 0.5;
    run.allow_stateful_with_sampling = true; // the documented escape hatch
    let mut rng = Pcg64::seed_from(6);
    let init = env.init_params(&mut rng);
    let hist = run.run(&env, init, &|p| env.evaluate(p));
    assert_eq!(hist.reports.len(), 5);
}

#[test]
fn zero_gradient_rounds_are_stable() {
    // A dataset of identical points with identical labels yields zero
    // gradients quickly; nothing should NaN or panic.
    let n = 32;
    let x = vec![0.0f32; n * 4];
    let y = vec![0usize; n];
    let data = Dataset { x, y, dim: 4, classes: 2 };
    let fed = FederatedDataset { shards: vec![(0..n).collect(); 2] };
    let env = ClassifierEnv::new(
        ModelKind::Linear { inputs: 4, classes: 2 }.build(),
        data.clone(),
        data,
        fed,
        8,
    );
    for kind in [
        CompressorKind::Sparsign { budget: 1.0 },
        CompressorKind::TernGrad,
        CompressorKind::Qsgd { levels: 1, norm: NormKind::L2 },
    ] {
        let run = base_run(Algorithm::CompressedGd {
            compressor: kind,
            aggregation: AggregationRule::MajorityVote,
        });
        let mut rng = Pcg64::seed_from(7);
        let init = env.init_params(&mut rng);
        let hist = run.run(&env, init, &|p| env.evaluate(p));
        assert!(hist.final_params.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn single_worker_single_example_trains() {
    let data = tiny_dataset(1);
    let fed = FederatedDataset { shards: vec![vec![0]] };
    let env = ClassifierEnv::new(
        ModelKind::Linear { inputs: 4, classes: 2 }.build(),
        data.clone(),
        data,
        fed,
        2,
    );
    let run = base_run(Algorithm::EfSparsign {
        b_local: 10.0,
        b_global: 1.0,
        tau: 2,
        server_lr_scale: None,
        server_ef: true,
    });
    let mut rng = Pcg64::seed_from(8);
    let init = env.init_params(&mut rng);
    let hist = run.run(&env, init, &|p| env.evaluate(p));
    assert!(hist.final_params.iter().all(|v| v.is_finite()));
}

#[test]
fn config_validation_rejects_garbage() {
    let mut cfg = ExperimentConfig::fast_preset();
    cfg.rounds = 0;
    assert!(cfg.validate().is_err());

    let mut cfg = ExperimentConfig::fast_preset();
    cfg.lr_overrides = vec![Some(0.1)]; // wrong arity
    assert!(cfg.validate().is_err());

    let mut cfg = ExperimentConfig::fast_preset();
    cfg.data_scale = 0.0;
    assert!(cfg.validate().is_err());

    let mut cfg = ExperimentConfig::fast_preset();
    assert!(cfg.apply_override("participation", "0.9").is_ok());
    assert!(cfg.apply_override("participation", "a lot").is_err());
}

#[test]
fn huge_gradients_do_not_break_bit_accounting() {
    let env = tiny_env();
    let mut run = base_run(Algorithm::CompressedGd {
        compressor: CompressorKind::Sparsign { budget: 1e6 }, // extreme clipping
        aggregation: AggregationRule::MajorityVote,
    });
    run.schedule = LrSchedule::Const { lr: 1e-6 };
    let mut rng = Pcg64::seed_from(9);
    let init = env.init_params(&mut rng);
    let hist = run.run(&env, init, &|p| env.evaluate(p));
    assert!(hist.total_uplink().is_finite());
    // Fully clipped sparsign = dense sign ⇒ uplink ≈ Golomb cost of a
    // (nearly) full support, still finite and bounded by ~2 bits/coord + d.
    use sparsignd::coordinator::GradientSource;
    let d = env.dim() as f64;
    let per_round = hist.total_uplink() / 5.0 / 4.0; // rounds, workers
    assert!(per_round <= 34.0 * d, "per-message bits {per_round} vs d {d}");
}
