//! Failure injection: the engine and its substrates must fail loudly and
//! precisely on invalid configurations, and stay numerically sane on
//! degenerate-but-legal inputs.

use sparsignd::compressors::{CompressedGrad, CompressorKind, NormKind, PackedTernary};
use sparsignd::config::ExperimentConfig;
use sparsignd::coordinator::{AggregationRule, Algorithm, ClassifierEnv, RunHistory, TrainingRun};
use sparsignd::data::{Dataset, DirichletPartitioner, FederatedDataset};
use sparsignd::model::ModelKind;
use sparsignd::net::wire::{self, WireBuf};
use sparsignd::net::{read_frame_bytes, Endpoint, Msg, NetCoordinator, RejectReason, ServeOptions};
use sparsignd::optim::LrSchedule;
use sparsignd::util::rng::Pcg64;
use std::io::Write as _;
use std::net::TcpStream;
use std::time::Duration;

fn tiny_dataset(n: usize) -> Dataset {
    let mut rng = Pcg64::seed_from(1);
    let dim = 4;
    let mut x = vec![0.0f32; n * dim];
    rng.fill_normal(&mut x, 0.0, 1.0);
    let y: Vec<usize> = (0..n).map(|i| i % 2).collect();
    Dataset { x: x.into(), y, dim, classes: 2 }
}

fn tiny_env() -> ClassifierEnv {
    let data = tiny_dataset(64);
    let mut rng = Pcg64::seed_from(2);
    let fed = DirichletPartitioner { alpha: 1.0, workers: 4 }.partition(&data, &mut rng);
    ClassifierEnv::new(
        ModelKind::Linear { inputs: 4, classes: 2 }.build(),
        data.clone(),
        data,
        fed,
        8,
    )
}

fn base_run(alg: Algorithm) -> TrainingRun {
    TrainingRun {
        algorithm: alg,
        schedule: LrSchedule::Const { lr: 0.1 },
        rounds: 5,
        participation: 1.0,
        eval_every: 0,
        seed: 0,
        attack: None,
        selection: Default::default(),
        allow_stateful_with_sampling: false,
        threads: None,
    }
}

#[test]
#[should_panic(expected = "init params dim mismatch")]
fn wrong_init_dim_rejected() {
    let env = tiny_env();
    let run = base_run(Algorithm::CompressedGd {
        compressor: CompressorKind::Sign,
        aggregation: AggregationRule::MajorityVote,
    });
    run.run(&env, vec![0.0; 3], &|p| env.evaluate(p));
}

#[test]
#[should_panic(expected = "at least one round")]
fn zero_rounds_rejected() {
    let env = tiny_env();
    let mut run = base_run(Algorithm::FedAvg { tau: 1 });
    run.rounds = 0;
    let mut rng = Pcg64::seed_from(3);
    let init = env.init_params(&mut rng);
    run.run(&env, init, &|p| env.evaluate(p));
}

#[test]
#[should_panic(expected = "participation must be in")]
fn bad_participation_rejected() {
    let env = tiny_env();
    let mut run = base_run(Algorithm::FedAvg { tau: 1 });
    run.participation = 1.5;
    let mut rng = Pcg64::seed_from(4);
    let init = env.init_params(&mut rng);
    run.run(&env, init, &|p| env.evaluate(p));
}

#[test]
#[should_panic(expected = "worker-side state")]
fn stale_ef_configuration_rejected_by_default() {
    let env = tiny_env();
    let mut run = base_run(Algorithm::CompressedGd {
        compressor: CompressorKind::WorkerEf(Box::new(CompressorKind::Sign)),
        aggregation: AggregationRule::MajorityVote,
    });
    run.participation = 0.5;
    let mut rng = Pcg64::seed_from(5);
    let init = env.init_params(&mut rng);
    run.run(&env, init, &|p| env.evaluate(p));
}

#[test]
fn stale_ef_override_runs_but_is_explicit() {
    let env = tiny_env();
    let mut run = base_run(Algorithm::CompressedGd {
        compressor: CompressorKind::WorkerEf(Box::new(CompressorKind::ScaledSign)),
        aggregation: AggregationRule::Mean,
    });
    run.participation = 0.5;
    run.allow_stateful_with_sampling = true; // the documented escape hatch
    let mut rng = Pcg64::seed_from(6);
    let init = env.init_params(&mut rng);
    let hist = run.run(&env, init, &|p| env.evaluate(p));
    assert_eq!(hist.reports.len(), 5);
}

#[test]
fn zero_gradient_rounds_are_stable() {
    // A dataset of identical points with identical labels yields zero
    // gradients quickly; nothing should NaN or panic.
    let n = 32;
    let x = vec![0.0f32; n * 4];
    let y = vec![0usize; n];
    let data = Dataset { x: x.into(), y, dim: 4, classes: 2 };
    let fed = FederatedDataset::from_shards(vec![(0..n).collect(); 2]);
    let env = ClassifierEnv::new(
        ModelKind::Linear { inputs: 4, classes: 2 }.build(),
        data.clone(),
        data,
        fed,
        8,
    );
    for kind in [
        CompressorKind::Sparsign { budget: 1.0 },
        CompressorKind::TernGrad,
        CompressorKind::Qsgd { levels: 1, norm: NormKind::L2 },
    ] {
        let run = base_run(Algorithm::CompressedGd {
            compressor: kind,
            aggregation: AggregationRule::MajorityVote,
        });
        let mut rng = Pcg64::seed_from(7);
        let init = env.init_params(&mut rng);
        let hist = run.run(&env, init, &|p| env.evaluate(p));
        assert!(hist.final_params.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn single_worker_single_example_trains() {
    let data = tiny_dataset(1);
    let fed = FederatedDataset::from_shards(vec![vec![0]]);
    let env = ClassifierEnv::new(
        ModelKind::Linear { inputs: 4, classes: 2 }.build(),
        data.clone(),
        data,
        fed,
        2,
    );
    let run = base_run(Algorithm::EfSparsign {
        b_local: 10.0,
        b_global: 1.0,
        tau: 2,
        server_lr_scale: None,
        server_ef: true,
    });
    let mut rng = Pcg64::seed_from(8);
    let init = env.init_params(&mut rng);
    let hist = run.run(&env, init, &|p| env.evaluate(p));
    assert!(hist.final_params.iter().all(|v| v.is_finite()));
}

#[test]
fn config_validation_rejects_garbage() {
    let mut cfg = ExperimentConfig::fast_preset();
    cfg.rounds = 0;
    assert!(cfg.validate().is_err());

    let mut cfg = ExperimentConfig::fast_preset();
    cfg.lr_overrides = vec![Some(0.1)]; // wrong arity
    assert!(cfg.validate().is_err());

    let mut cfg = ExperimentConfig::fast_preset();
    cfg.data_scale = 0.0;
    assert!(cfg.validate().is_err());

    let mut cfg = ExperimentConfig::fast_preset();
    assert!(cfg.apply_override("participation", "0.9").is_ok());
    assert!(cfg.apply_override("participation", "a lot").is_err());
}

// ---------------------------------------------------------------------
// Transport faults (DESIGN.md §11): the coordinator service must keep
// rounds completing under dropped clients, duplicate submissions and
// deadline-expired stragglers — failing loudly only when a round gets
// zero submissions.
// ---------------------------------------------------------------------

/// A hand-driven wire client for fault injection: speaks raw frames
/// over TCP so tests control exactly what (and when) the server sees.
struct RawClient {
    stream: TcpStream,
    wbuf: WireBuf,
    out: Vec<u8>,
    buf: Vec<u8>,
}

impl RawClient {
    fn connect(ep: &Endpoint) -> Self {
        let Endpoint::Tcp(addr) = ep else { panic!("fault tests speak tcp") };
        let stream = TcpStream::connect(addr).expect("connect");
        stream.set_nodelay(true).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        Self { stream, wbuf: WireBuf::new(), out: Vec::new(), buf: Vec::new() }
    }

    fn send(&mut self, msg: &Msg) -> usize {
        self.out.clear();
        let n = self.wbuf.encode(msg, &mut self.out);
        self.stream.write_all(&self.out).expect("send frame");
        n
    }

    fn send_update(&mut self, t: u64, worker: u64, d: usize) -> usize {
        // Any unit-scale ternary payload is protocol-valid; the fault
        // tests assert protocol behavior, not training math.
        let pack = PackedTernary::dense_signs(&vec![0.5f32; d], 1.0);
        let grad = CompressedGrad::ternary(pack, 2.0 * d as f64);
        self.out.clear();
        let n = self.wbuf.encode_update(t, worker, 0.25, &grad, &mut self.out);
        self.stream.write_all(&self.out).expect("send update");
        n
    }

    fn recv(&mut self) -> Msg {
        let n = read_frame_bytes(&mut self.stream, wire::MAX_PAYLOAD, &mut self.buf)
            .expect("read frame");
        let (frame, _) = wire::parse_frame(&self.buf[..n], wire::MAX_PAYLOAD).unwrap();
        wire::decode_msg(frame).unwrap()
    }

    /// Rendezvous with the run-config fingerprint the coordinator will
    /// demand (`TrainingRun::config_fingerprint(d, m, 0)`); env hash 0
    /// because these fault harnesses serve without one.
    fn join(&mut self, lo: u64, hi: u64, cfg: u64) {
        self.send(&Msg::Hello { lo, hi, cfg, env: 0 });
        let Msg::Welcome { .. } = self.recv() else { panic!("expected Welcome") };
    }

    /// Receive, asserting a round-open; returns `(t, lr, selected)`.
    fn expect_round(&mut self) -> (u64, f64, Vec<u64>) {
        match self.recv() {
            Msg::RoundOpen { t, lr, selected, .. } => (t, lr, selected),
            other => panic!("expected RoundOpen, got {other:?}"),
        }
    }
}

fn net_run(rounds: usize) -> TrainingRun {
    let mut run = base_run(Algorithm::CompressedGd {
        compressor: CompressorKind::Sign,
        aggregation: AggregationRule::MajorityVote,
    });
    run.rounds = rounds;
    run
}

/// Bind a TCP coordinator and serve `run` from a scoped thread while
/// `fleet` drives hand-rolled clients; returns the server history.
fn serve_with<F>(
    run: &TrainingRun,
    m: usize,
    d: usize,
    deadline: Option<Duration>,
    fleet: F,
) -> RunHistory
where
    F: FnOnce(&Endpoint),
{
    let mut opts = ServeOptions::new(Endpoint::Tcp("127.0.0.1:0".into()));
    opts.round_deadline = deadline;
    opts.rendezvous_timeout = Duration::from_secs(20);
    let coordinator = NetCoordinator::bind(opts).expect("bind");
    let ep = coordinator.local_endpoint().clone();
    let mut hist = None;
    std::thread::scope(|s| {
        let handle = s.spawn(|| coordinator.serve(run, m, vec![0.0f32; d], &|_p| (0.0, 0.0)));
        fleet(&ep);
        hist = Some(handle.join().expect("server thread").expect("serve"));
    });
    hist.unwrap()
}

#[test]
fn transport_dropped_client_mid_round_still_completes() {
    let d = 8;
    let run = net_run(2);
    let cfg = run.config_fingerprint(d, 3, 0);
    let hist = serve_with(&run, 3, d, None, |ep| {
        let mut a = RawClient::connect(ep);
        let mut b = RawClient::connect(ep);
        a.join(0, 2, cfg);
        b.join(2, 3, cfg);
        // B sees round 0 open, then dies without submitting.
        let _ = b.expect_round();
        drop(b);
        for _ in 0..2 {
            let (t, _lr, selected) = a.expect_round();
            for &w in &selected {
                a.send_update(t, w, d);
            }
        }
        let Msg::Fin { rounds } = a.recv() else { panic!("expected Fin") };
        assert_eq!(rounds, 2);
    });
    assert_eq!(hist.reports.len(), 2);
    // B's worker was selected (full participation) but never delivered:
    // one straggler per round, two senders per round.
    assert_eq!(hist.ledger.total_stragglers(), 2);
    for t in 0..2 {
        let rc = hist.ledger.get(t).unwrap();
        assert_eq!(rc.senders, 2, "round {t}");
        assert_eq!(rc.stragglers, 1, "round {t}");
    }
    assert!(hist.final_params.iter().all(|v| v.is_finite()));
}

#[test]
fn transport_duplicate_submission_is_idempotently_rejected() {
    let d = 8;
    let run = net_run(1);
    let cfg = run.config_fingerprint(d, 2, 0);
    let hist = serve_with(&run, 2, d, None, |ep| {
        let mut c = RawClient::connect(ep);
        c.join(0, 2, cfg);
        let (t, _lr, selected) = c.expect_round();
        assert_eq!(selected, vec![0, 1]);
        let len0 = c.send_update(t, 0, d);
        let dup = c.send_update(t, 0, d); // identical resend
        assert_eq!(dup, len0);
        let len1 = c.send_update(t, 1, d);
        match c.recv() {
            Msg::Reject { t: rt, worker, reason } => {
                assert_eq!((rt, worker), (0, 0));
                assert_eq!(reason, RejectReason::Duplicate);
            }
            other => panic!("expected duplicate reject, got {other:?}"),
        }
        let Msg::Fin { .. } = c.recv() else { panic!("expected Fin") };
        // The ledger counted the two accepted frames, not the duplicate.
        assert_eq!(len0, len1);
    });
    let rc = hist.ledger.get(0).unwrap();
    assert_eq!(rc.senders, 2);
    assert_eq!(rc.stragglers, 0);
    // The ledger counted exactly the two accepted frames, not the
    // duplicate: recompute one update frame's length for the sum.
    let pack = PackedTernary::dense_signs(&vec![0.5f32; 8], 1.0);
    let grad = CompressedGrad::ternary(pack, 16.0);
    let mut wbuf = WireBuf::new();
    let mut out = Vec::new();
    let one = wbuf.encode_update(0, 0, 0.25, &grad, &mut out) as u64;
    assert_eq!(rc.uplink_wire_bytes, 2 * one);
}

#[test]
fn transport_deadline_expired_straggler_is_counted() {
    let d = 8;
    let run = net_run(2);
    let deadline = Some(Duration::from_millis(2000));
    let cfg = run.config_fingerprint(d, 2, 0);
    let hist = serve_with(&run, 2, d, deadline, |ep| {
        let mut a = RawClient::connect(ep);
        let mut b = RawClient::connect(ep);
        a.join(0, 1, cfg);
        b.join(1, 2, cfg);
        // A is prompt in both rounds.
        let (t0, _, sel) = a.expect_round();
        for &w in &sel {
            a.send_update(t0, w, d);
        }
        // B reads round 0 but sleeps through its deadline.
        let (bt0, _, bsel) = b.expect_round();
        assert_eq!((bt0, bsel.as_slice()), (0, &[1u64][..]));
        std::thread::sleep(Duration::from_millis(3000));
        // Late: round 0 closed long ago (server is in round 1 by now).
        b.send_update(0, 1, d);
        // A finishes round 1 as soon as it opens …
        let (t1, _, sel) = a.expect_round();
        assert_eq!(t1, 1);
        for &w in &sel {
            a.send_update(t1, w, d);
        }
        // … while B recovers in round 1 after its stale-round reject.
        let (bt1, _, bsel) = b.expect_round();
        assert_eq!(bt1, 1);
        for &w in &bsel {
            b.send_update(bt1, w, d);
        }
        match b.recv() {
            Msg::Reject { t, worker, reason } => {
                assert_eq!((t, worker), (0, 1));
                assert_eq!(reason, RejectReason::BadRound, "stale round is typed");
            }
            other => panic!("expected stale-round reject, got {other:?}"),
        }
        let Msg::Fin { .. } = a.recv() else { panic!("A expected Fin") };
        let Msg::Fin { .. } = b.recv() else { panic!("B expected Fin") };
    });
    assert_eq!(hist.reports.len(), 2);
    let r0 = hist.ledger.get(0).unwrap();
    assert_eq!((r0.senders, r0.stragglers), (1, 1), "round 0 closed at the deadline");
    let r1 = hist.ledger.get(1).unwrap();
    assert_eq!((r1.senders, r1.stragglers), (2, 0), "round 1 recovered");
}

#[test]
fn transport_claim_then_drop_completes_long_before_the_deadline() {
    // The satellite bug shape: a client that claims a roster range and
    // disconnects before its first update frame must be surfaced through
    // the dead-conn bookkeeping *immediately* (roster release + table
    // expectation shrink), not discovered when the round deadline
    // expires. With a 20 s deadline and 2 rounds, a deadline-stall
    // implementation would take ≥ 40 s; the immediate path takes
    // milliseconds.
    let d = 8;
    let run = net_run(2);
    let deadline = Some(Duration::from_secs(20));
    let cfg = run.config_fingerprint(d, 3, 0);
    let t0 = std::time::Instant::now();
    let hist = serve_with(&run, 3, d, deadline, |ep| {
        let mut a = RawClient::connect(ep);
        let mut b = RawClient::connect(ep);
        a.join(0, 2, cfg);
        b.join(2, 3, cfg);
        // B claimed workers 2..3 and dies before any update frame.
        let _ = b.expect_round();
        drop(b);
        for _ in 0..2 {
            let (t, _lr, selected) = a.expect_round();
            for &w in &selected {
                a.send_update(t, w, d);
            }
        }
        let Msg::Fin { .. } = a.recv() else { panic!("expected Fin") };
    });
    let elapsed = t0.elapsed();
    assert!(
        elapsed < Duration::from_secs(10),
        "rounds stalled {elapsed:?} against a 20 s deadline — dead conns must \
         shrink expectations immediately"
    );
    assert_eq!(hist.reports.len(), 2);
    assert_eq!(hist.ledger.total_stragglers(), 2, "B's worker is a straggler both rounds");
}

#[test]
fn transport_empty_round_waits_for_recoverage_instead_of_dying() {
    // The whole cohort's host dies before submitting anything: the round
    // closes with zero live submissions, but instead of aborting the run
    // the coordinator waits (bounded by the rendezvous timeout) for a
    // replacement to re-claim the range, then re-broadcasts the *same*
    // round — worker rounds are pure, so the recomputation is harmless.
    let d = 8;
    let run = net_run(2);
    let cfg = run.config_fingerprint(d, 2, 0);
    let hist = serve_with(&run, 2, d, None, |ep| {
        let mut a1 = RawClient::connect(ep);
        a1.join(0, 2, cfg);
        // Receive round 0's broadcast, then die without a single update.
        let _ = a1.expect_round();
        drop(a1);
        std::thread::sleep(Duration::from_millis(400));
        let mut a2 = RawClient::connect(ep);
        a2.join(0, 2, cfg); // re-claims the whole population
        for _ in 0..2 {
            let (t, _lr, sel) = a2.expect_round();
            for &w in &sel {
                a2.send_update(t, w, d);
            }
        }
        let Msg::Fin { .. } = a2.recv() else { panic!("expected Fin") };
    });
    assert_eq!(hist.reports.len(), 2);
    // The re-broadcast attempt completed in full: no stragglers recorded.
    for t in 0..2 {
        let rc = hist.ledger.get(t).unwrap();
        assert_eq!((rc.senders, rc.stragglers), (2, 0), "round {t}");
    }
}

#[test]
fn transport_dead_range_is_reclaimed_by_a_reconnecting_client() {
    // Elastic churn: when a client dies its roster claim is released, so
    // a replacement can re-claim the same worker range mid-run and serve
    // from the next round — instead of bouncing off ClaimError::Overlap
    // forever.
    let d = 8;
    let run = net_run(3);
    let cfg = run.config_fingerprint(d, 2, 0);
    let hist = serve_with(&run, 2, d, None, |ep| {
        let mut a = RawClient::connect(ep);
        let mut b1 = RawClient::connect(ep);
        a.join(0, 1, cfg);
        b1.join(1, 2, cfg);
        // Round 0: both submit.
        let (t, _lr, sel) = a.expect_round();
        for &w in &sel {
            a.send_update(t, w, d);
        }
        let (t, _lr, sel) = b1.expect_round();
        for &w in &sel {
            b1.send_update(t, w, d);
        }
        // B1 dies. Give the coordinator time to process Gone (release
        // the claim + drop the slot) before the replacement dials in.
        drop(b1);
        std::thread::sleep(Duration::from_millis(400));
        let mut b2 = RawClient::connect(ep);
        b2.join(1, 2, cfg); // re-claims the freed range mid-run
        // A carries round 1 alone (B1's slot was dropped immediately).
        let (t, _lr, sel) = a.expect_round();
        assert_eq!(t, 1);
        for &w in &sel {
            a.send_update(t, w, d);
        }
        // Round 2: both hosts serve again.
        let (t, _lr, sel) = a.expect_round();
        assert_eq!(t, 2);
        for &w in &sel {
            a.send_update(t, w, d);
        }
        let (t, _lr, sel) = b2.expect_round();
        assert_eq!((t, sel.as_slice()), (2, &[1u64][..]));
        for &w in &sel {
            b2.send_update(t, w, d);
        }
        let Msg::Fin { .. } = a.recv() else { panic!("A expected Fin") };
        let Msg::Fin { .. } = b2.recv() else { panic!("B2 expected Fin") };
    });
    assert_eq!(hist.reports.len(), 3);
    let senders: Vec<usize> = (0..3).map(|t| hist.ledger.get(t).unwrap().senders).collect();
    let stragglers: Vec<usize> =
        (0..3).map(|t| hist.ledger.get(t).unwrap().stragglers).collect();
    assert_eq!(senders, vec![2, 1, 2], "round 1 runs without B, round 2 with B2");
    assert_eq!(stragglers, vec![0, 1, 0]);
}

#[test]
fn huge_gradients_do_not_break_bit_accounting() {
    let env = tiny_env();
    let mut run = base_run(Algorithm::CompressedGd {
        compressor: CompressorKind::Sparsign { budget: 1e6 }, // extreme clipping
        aggregation: AggregationRule::MajorityVote,
    });
    run.schedule = LrSchedule::Const { lr: 1e-6 };
    let mut rng = Pcg64::seed_from(9);
    let init = env.init_params(&mut rng);
    let hist = run.run(&env, init, &|p| env.evaluate(p));
    assert!(hist.total_uplink().is_finite());
    // Fully clipped sparsign = dense sign ⇒ uplink ≈ Golomb cost of a
    // (nearly) full support, still finite and bounded by ~2 bits/coord + d.
    use sparsignd::coordinator::GradientSource;
    let d = env.dim() as f64;
    let per_round = hist.total_uplink() / 5.0 / 4.0; // rounds, workers
    assert!(per_round <= 34.0 * d, "per-message bits {per_round} vs d {d}");
}
