//! Elastic-federation equivalence (DESIGN.md §12): a run interrupted by
//! a coordinator snapshot + restart must produce a `RunHistory`
//! **bit-identical** to an uninterrupted run — in-process (periodic
//! snapshots + `resume_from`) and over the wire (coordinator drain,
//! fleet reconnect-with-backoff, `--resume`-style successor).
//!
//! The determinism contract makes this provable rather than hopeful:
//! worker RNG streams are derived per `(seed, round, worker)` and never
//! persist, so the snapshot's params + selection stream + server
//! residual + history are a complete cut of the run's state.

use std::sync::Mutex;
use std::time::Duration;

use sparsignd::compressors::CompressorKind;
use sparsignd::coordinator::{AggregationRule, Algorithm, ClassifierEnv, RunHistory, TrainingRun};
use sparsignd::data::{DirichletPartitioner, SyntheticSpec, SyntheticTask};
use sparsignd::model::ModelKind;
use sparsignd::net::client::loopback_endpoint;
use sparsignd::net::{
    run_fleet_src, run_loopback, Endpoint, FleetOptions, NetCoordinator, NetError, ServeOptions,
};
use sparsignd::optim::LrSchedule;
use sparsignd::snapshot::{CoordinatorSnapshot, SnapshotError, SnapshotPolicy};
use sparsignd::util::rng::Pcg64;

fn env_with_alpha(workers: usize, alpha: f64) -> ClassifierEnv {
    let task = SyntheticTask::generate(
        SyntheticSpec {
            dim: 12,
            classes: 3,
            modes: 1,
            separation: 1.8,
            noise: 0.25,
            label_noise: 0.0,
            train: 480,
            test: 120,
        },
        41,
    );
    let mut rng = Pcg64::seed_from(42);
    let fed = DirichletPartitioner { alpha, workers }.partition(&task.train, &mut rng);
    ClassifierEnv::new(
        ModelKind::Linear { inputs: 12, classes: 3 }.build(),
        task.train,
        task.test,
        fed,
        16,
    )
}

fn env(workers: usize) -> ClassifierEnv {
    env_with_alpha(workers, 0.5)
}

fn base_run(alg: Algorithm, rounds: usize) -> TrainingRun {
    let mut run = TrainingRun::new(alg, LrSchedule::Const { lr: 0.05 }, rounds);
    run.eval_every = 3;
    run.seed = 17;
    run
}

fn sign_vote(rounds: usize) -> TrainingRun {
    base_run(
        Algorithm::CompressedGd {
            compressor: CompressorKind::Sign,
            aggregation: AggregationRule::MajorityVote,
        },
        rounds,
    )
}

/// Field-exact equality, ledger included (wire bytes and stragglers too).
fn assert_identical(a: &RunHistory, b: &RunHistory) {
    assert_eq!(a.final_params, b.final_params, "final params");
    assert_eq!(a.reports, b.reports, "round reports");
    assert_eq!(a.ledger, b.ledger, "communication ledger");
}

fn snap_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("sparsignd-resume-{}-{tag}.snap", std::process::id()))
}

#[test]
fn in_process_snapshot_and_resume_are_bit_identical() {
    let e = env(10);
    let mut rng = Pcg64::seed_from(43);
    let init = e.init_params(&mut rng);
    let run = sign_vote(6);
    let path = snap_path("inproc");

    let plain = run.run(&e, init.clone(), &|p| e.evaluate(p));
    // Snapshotting must not perturb the run…
    let policy = SnapshotPolicy::every(&path, 4);
    let snapped = run
        .run_snapshotted(&e, init.clone(), &|p| e.evaluate(p), &policy)
        .expect("snapshotted run");
    assert_identical(&plain, &snapped);
    // …and resuming from the round-4 snapshot replays rounds 4..6 onto
    // the restored state, bit-identically.
    let snap = CoordinatorSnapshot::load(&path).expect("load snapshot");
    assert_eq!(snap.next_round(), 4);
    let resumed = run.resume_from(&e, snap, &|p| e.evaluate(p), None).expect("resume");
    assert_identical(&plain, &resumed);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn serial_and_pool_engines_resume_identically() {
    let e = env(8);
    let mut rng = Pcg64::seed_from(44);
    let init = e.init_params(&mut rng);
    let path = snap_path("serial");

    let mut serial = sign_vote(5);
    serial.threads = Some(1);
    let plain = serial.run(&e, init.clone(), &|p| e.evaluate(p));
    let policy = SnapshotPolicy::every(&path, 2);
    serial
        .run_snapshotted(&e, init.clone(), &|p| e.evaluate(p), &policy)
        .expect("serial snapshotted run");
    // The last periodic snapshot lands at round 4 (2 and 4 are due).
    let snap = CoordinatorSnapshot::load(&path).expect("load");
    assert_eq!(snap.next_round(), 4);
    // Resume on the *pool* engine: the snapshot is engine-agnostic.
    let mut pooled = sign_vote(5);
    pooled.threads = Some(4);
    let resumed = pooled.resume_from(&e, snap, &|p| e.evaluate(p), None).expect("resume");
    assert_identical(&plain, &resumed);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn partial_participation_resume_continues_the_selection_stream() {
    let e = env(10);
    let mut rng = Pcg64::seed_from(45);
    let init = e.init_params(&mut rng);
    let mut run = sign_vote(6);
    run.participation = 0.5;
    let path = snap_path("partial");

    let plain = run.run(&e, init.clone(), &|p| e.evaluate(p));
    let policy = SnapshotPolicy::every(&path, 3);
    run.run_snapshotted(&e, init.clone(), &|p| e.evaluate(p), &policy).expect("snapshotted");
    let snap = CoordinatorSnapshot::load(&path).expect("load");
    assert_eq!(snap.next_round(), 3);
    // Rounds 3..6 draw fresh selections from the restored RNG stream;
    // any drift would change which workers participate and diverge the
    // reports immediately.
    let resumed = run.resume_from(&e, snap, &|p| e.evaluate(p), None).expect("resume");
    assert_identical(&plain, &resumed);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn ef_sparsign_resume_restores_the_server_residual() {
    let e = env(8);
    let mut rng = Pcg64::seed_from(46);
    let init = e.init_params(&mut rng);
    let run = base_run(
        Algorithm::EfSparsign {
            b_local: 10.0,
            b_global: 1.0,
            tau: 2,
            server_lr_scale: None,
            server_ef: true,
        },
        6,
    );
    let path = snap_path("ef");

    let plain = run.run(&e, init.clone(), &|p| e.evaluate(p));
    let policy = SnapshotPolicy::every(&path, 3);
    run.run_snapshotted(&e, init.clone(), &|p| e.evaluate(p), &policy).expect("snapshotted");
    let snap = CoordinatorSnapshot::load(&path).expect("load");
    assert!(snap.residual.is_some(), "EF snapshot must carry the eq. (8) residual");
    let resumed = run.resume_from(&e, snap, &|p| e.evaluate(p), None).expect("resume");
    assert_identical(&plain, &resumed);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn stateful_worker_compressors_cannot_snapshot() {
    let e = env(6);
    let mut rng = Pcg64::seed_from(47);
    let init = e.init_params(&mut rng);
    let run = base_run(
        Algorithm::CompressedGd {
            compressor: CompressorKind::WorkerEf(Box::new(CompressorKind::Sign)),
            aggregation: AggregationRule::ScaledSign,
        },
        3,
    );
    let policy = SnapshotPolicy::every(snap_path("stateful"), 1);
    let err = run
        .run_snapshotted(&e, init, &|p| e.evaluate(p), &policy)
        .expect_err("worker-side state cannot ride a coordinator snapshot");
    assert!(matches!(err, SnapshotError::Unsupported(_)), "{err}");
}

#[test]
fn resume_refuses_a_different_run() {
    let e = env(8);
    let mut rng = Pcg64::seed_from(48);
    let init = e.init_params(&mut rng);
    let run = sign_vote(6);
    let path = snap_path("fingerprint");
    let policy = SnapshotPolicy::every(&path, 3);
    run.run_snapshotted(&e, init, &|p| e.evaluate(p), &policy).expect("snapshotted");
    let snap = CoordinatorSnapshot::load(&path).expect("load");

    // Same shape, different seed ⇒ different trajectory ⇒ refused.
    let mut other = sign_vote(6);
    other.seed = 18;
    let err = other
        .resume_from(&e, snap.clone(), &|p| e.evaluate(p), None)
        .expect_err("seed mismatch must be refused");
    assert!(matches!(err, SnapshotError::Incompatible(_)), "{err}");

    // Different round budget ⇒ refused before the fingerprint even runs.
    let shorter = sign_vote(5);
    let err = shorter
        .resume_from(&e, snap.clone(), &|p| e.evaluate(p), None)
        .expect_err("round-budget mismatch must be refused");
    assert!(matches!(err, SnapshotError::Incompatible(_)), "{err}");

    // Same run config, same shape (d, M) — but the dataset partition was
    // rebuilt with a different Dirichlet α. Only the environment
    // fingerprint can see this drift, and it must refuse.
    let drifted = env_with_alpha(8, 5.0);
    let err = run
        .resume_from(&drifted, snap, &|p| drifted.evaluate(p), None)
        .expect_err("environment drift must be refused");
    assert!(matches!(err, SnapshotError::Incompatible(_)), "{err}");
    let _ = std::fs::remove_file(&path);
}

/// The full elastic path over a real socket: coordinator 1 serves three
/// rounds, snapshots, drains (connections closed, no `Fin`); the fleet
/// reconnects with backoff; coordinator 2 — a fresh bind on a fresh
/// endpoint, exactly like a restarted process — resumes from the
/// snapshot, re-rosters the same virtual clients and finishes the run.
/// The stitched history must be bit-identical to an uninterrupted
/// loopback run.
fn drain_and_resume(uds: bool, tag: &str) {
    let workers = 12;
    let rounds = 6;
    let e = env(workers);
    let mut rng = Pcg64::seed_from(49);
    let init = e.init_params(&mut rng);
    let run = sign_vote(rounds);
    let agents = 3;
    let path = snap_path(tag);

    // Uninterrupted reference (same agent fan-out so the per-connection
    // downlink wire bytes match too).
    let fleet_opts = FleetOptions { agents, ..FleetOptions::default() };
    let eval = |p: &[f32]| e.evaluate(p);
    let (reference, _) = run_loopback(
        &run,
        &e,
        init.clone(),
        &eval,
        ServeOptions::new(loopback_endpoint(uds)),
        &fleet_opts,
    )
    .expect("uninterrupted loopback");

    // Interrupted: coordinator 1 drains after round 3.
    let mut opts1 = ServeOptions::new(loopback_endpoint(uds));
    opts1.snapshot = Some(SnapshotPolicy::on_drain(&path));
    opts1.drain_after = Some(3);
    let c1 = NetCoordinator::bind(opts1).expect("bind c1");
    let src = Mutex::new(c1.local_endpoint().clone());
    let elastic_opts = FleetOptions {
        agents,
        reconnect: Some(Duration::from_secs(30)),
        ..FleetOptions::default()
    };

    let mut resumed: Option<RunHistory> = None;
    let mut stats = None;
    std::thread::scope(|s| {
        let h1 = s.spawn(|| c1.serve(&run, workers, init.clone(), &eval));
        let fleet = s.spawn(|| run_fleet_src(&src, &run, &e, &elastic_opts));

        // Coordinator 1 exits through the drain path with the snapshot
        // on disk and its connections closed.
        match h1.join().expect("c1 thread") {
            Err(NetError::Drained { rounds_done }) => assert_eq!(rounds_done, 3),
            other => panic!("expected drain, got {other:?}"),
        }
        let snap = CoordinatorSnapshot::load(&path).expect("drain snapshot");
        assert_eq!(snap.next_round(), 3);

        // Coordinator 2: fresh bind (fresh endpoint — a restarted
        // process), resume from the snapshot, publish the new address.
        let mut opts2 = ServeOptions::new(loopback_endpoint(uds));
        opts2.resume = Some(snap);
        let c2 = NetCoordinator::bind(opts2).expect("bind c2");
        *src.lock().unwrap() = c2.local_endpoint().clone();
        let hist = c2.serve(&run, workers, init.clone(), &eval).expect("resumed serve");
        resumed = Some(hist);
        stats = Some(fleet.join().expect("fleet thread").expect("fleet"));
    });

    let resumed = resumed.expect("resumed history");
    let stats = stats.expect("fleet stats");
    assert!(stats.reconnects >= 1, "the fleet must have reconnected: {stats:?}");
    assert_eq!(stats.rejected, 0, "resume must not provoke rejects: {stats:?}");
    assert_identical(&reference, &resumed);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn coordinator_drain_and_resume_is_bit_identical_over_tcp() {
    drain_and_resume(false, "tcp");
}

#[cfg(unix)]
#[test]
fn coordinator_drain_and_resume_is_bit_identical_over_uds() {
    drain_and_resume(true, "uds");
}

/// A fleet built from drifted flags (different seed here — the same
/// holds for schedule/compressor/α/batch drift) is hung up on at
/// rendezvous: wire v2's `Hello` carries the run-config + environment
/// fingerprints, so the coordinator refuses instead of silently
/// diverging the run.
#[test]
fn drifted_fleet_is_refused_at_rendezvous() {
    let e = env(6);
    let mut rng = Pcg64::seed_from(50);
    let init = e.init_params(&mut rng);
    let run = sign_vote(3);
    let mut opts = ServeOptions::new(loopback_endpoint(false));
    opts.rendezvous_timeout = Duration::from_secs(3);
    let c = NetCoordinator::bind(opts).expect("bind");
    let ep = c.local_endpoint().clone();
    std::thread::scope(|s| {
        let eval = |p: &[f32]| e.evaluate(p);
        let h = s.spawn(|| c.serve(&run, 6, init.clone(), &eval));
        let mut drifted = sign_vote(3);
        drifted.seed = 99;
        let fleet_opts = FleetOptions { agents: 2, ..FleetOptions::default() };
        let err = run_fleet_src(&ep, &drifted, &e, &fleet_opts)
            .expect_err("drifted fleet must be refused");
        assert!(matches!(err, NetError::Disconnected | NetError::Io(_)), "{err}");
        // The coordinator never rendezvouses with a drifted fleet.
        let serve_err = h.join().expect("serve thread").expect_err("rendezvous must time out");
        assert!(matches!(serve_err, NetError::Protocol(_)), "{serve_err}");
    });
}

/// Reconnect gating: replaying rounds into stateful worker compressors
/// would double-advance their state, so the fleet refuses up front.
#[test]
fn reconnect_with_stateful_compressor_is_refused() {
    let e = env(4);
    let run = base_run(
        Algorithm::CompressedGd {
            compressor: CompressorKind::WorkerEf(Box::new(CompressorKind::Sign)),
            aggregation: AggregationRule::ScaledSign,
        },
        2,
    );
    let opts = FleetOptions {
        reconnect: Some(Duration::from_secs(1)),
        ..FleetOptions::default()
    };
    let ep = Endpoint::Tcp("127.0.0.1:1".into()); // never dialed
    let err = run_fleet_src(&ep, &run, &e, &opts).expect_err("must refuse");
    assert!(matches!(err, NetError::Config(_)), "{err}");
}
