//! End-to-end federation transport equivalence (DESIGN.md §11): a full
//! loopback run — compress, frame, send over a real socket, decode,
//! vote, broadcast — must produce a `RunHistory` **bit-identical** to
//! the in-process engine on the same seed, for both aggregation routes:
//!
//! * streaming (unit-scale packed ternary → `VoteAccumulator`):
//!   `Sign × ScaledSign` — the server folds frames as they arrive and
//!   never buffers the cohort;
//! * buffered (per-message scales): `TernGrad × Mean` — messages are
//!   slotted and aggregated by the reference route.
//!
//! Both TCP and (on unix) UDS transports are exercised, plus partial
//! participation (the selection RNG lives server-side) and the
//! wire-byte ledger layer.

use sparsignd::compressors::CompressorKind;
use sparsignd::coordinator::{AggregationRule, Algorithm, ClassifierEnv, RunHistory, TrainingRun};
use sparsignd::data::{DirichletPartitioner, SyntheticSpec, SyntheticTask};
use sparsignd::model::ModelKind;
use sparsignd::net::client::loopback_endpoint;
use sparsignd::net::{run_loopback, FleetOptions, ServeOptions};
use sparsignd::optim::LrSchedule;
use sparsignd::util::rng::Pcg64;

fn env(workers: usize) -> ClassifierEnv {
    let task = SyntheticTask::generate(
        SyntheticSpec {
            dim: 12,
            classes: 3,
            modes: 1,
            separation: 1.8,
            noise: 0.25,
            label_noise: 0.0,
            train: 480,
            test: 120,
        },
        31,
    );
    let mut rng = Pcg64::seed_from(32);
    let fed = DirichletPartitioner { alpha: 0.5, workers }.partition(&task.train, &mut rng);
    ClassifierEnv::new(
        ModelKind::Linear { inputs: 12, classes: 3 }.build(),
        task.train,
        task.test,
        fed,
        16,
    )
}

fn base_run(alg: Algorithm, rounds: usize) -> TrainingRun {
    let mut run = TrainingRun::new(alg, LrSchedule::Const { lr: 0.05 }, rounds);
    run.eval_every = 3;
    run.seed = 11;
    run
}

fn assert_identical(a: &RunHistory, b: &RunHistory) {
    assert_eq!(a.final_params, b.final_params, "final params");
    assert_eq!(a.reports.len(), b.reports.len());
    for (ra, rb) in a.reports.iter().zip(&b.reports) {
        assert_eq!(ra.train_loss, rb.train_loss, "round {}", ra.round);
        assert_eq!(ra.uplink_bits, rb.uplink_bits, "round {}", ra.round);
        assert_eq!(ra.downlink_bits, rb.downlink_bits, "round {}", ra.round);
        assert_eq!(ra.cum_uplink_bits, rb.cum_uplink_bits, "round {}", ra.round);
        assert_eq!(ra.eval, rb.eval, "round {}", ra.round);
    }
    assert_eq!(a.ledger.total_uplink(), b.ledger.total_uplink());
    assert_eq!(a.ledger.total_downlink(), b.ledger.total_downlink());
    assert_eq!(a.ledger.total_uplink_nnz(), b.ledger.total_uplink_nnz());
}

/// Run `run` in-process and over a loopback transport; pin equality and
/// return the transport history for further ledger checks.
fn loopback_vs_in_process(
    run: &TrainingRun,
    workers: usize,
    uds: bool,
    agents: usize,
) -> RunHistory {
    let e = env(workers);
    let mut rng = Pcg64::seed_from(33);
    let init = e.init_params(&mut rng);
    let in_process = run.run(&e, init.clone(), &|p| e.evaluate(p));

    let serve_opts = ServeOptions::new(loopback_endpoint(uds));
    let fleet_opts = FleetOptions::new().with_agents(agents);
    let eval = |p: &[f32]| e.evaluate(p);
    let (wire_hist, stats) =
        run_loopback(run, &e, init, &eval, serve_opts, &fleet_opts).expect("loopback run");
    assert_identical(&in_process, &wire_hist);

    // The wire layer recorded real bytes; the in-process run recorded
    // none. The ledger's uplink bytes are exactly the accepted update
    // frames, i.e. the fleet's total upload minus its per-agent
    // rendezvous chatter (one Hello + one Heartbeat each).
    assert_eq!(in_process.ledger.total_uplink_wire_bytes(), 0);
    let up = wire_hist.ledger.total_uplink_wire_bytes();
    assert!(up > 0 && up <= stats.bytes_up, "{up} vs fleet {}", stats.bytes_up);
    assert!(up + 100 * agents as u64 >= stats.bytes_up, "{up} vs fleet {}", stats.bytes_up);
    assert!(wire_hist.ledger.total_downlink_wire_bytes() > 0);
    assert_eq!(wire_hist.ledger.total_stragglers(), 0);
    assert_eq!(stats.rejected, 0);
    assert!(stats.updates_sent > 0);
    wire_hist
}

#[test]
fn streaming_sign_scaledsign_matches_in_process_over_tcp() {
    let run = base_run(
        Algorithm::CompressedGd {
            compressor: CompressorKind::Sign,
            aggregation: AggregationRule::ScaledSign,
        },
        6,
    );
    loopback_vs_in_process(&run, 10, false, 3);
}

#[cfg(unix)]
#[test]
fn streaming_sparsign_matches_in_process_over_uds() {
    let run = base_run(
        Algorithm::CompressedGd {
            compressor: CompressorKind::Sparsign { budget: 0.7 },
            aggregation: AggregationRule::MajorityVote,
        },
        6,
    );
    loopback_vs_in_process(&run, 12, true, 4);
}

#[test]
fn buffered_terngrad_mean_matches_in_process() {
    let run = base_run(
        Algorithm::CompressedGd {
            compressor: CompressorKind::TernGrad,
            aggregation: AggregationRule::Mean,
        },
        5,
    );
    loopback_vs_in_process(&run, 8, false, 2);
}

#[test]
fn partial_participation_selection_lives_server_side() {
    let mut run = base_run(
        Algorithm::CompressedGd {
            compressor: CompressorKind::Sign,
            aggregation: AggregationRule::MajorityVote,
        },
        8,
    );
    run.participation = 0.5;
    let hist = loopback_vs_in_process(&run, 10, false, 3);
    for t in 0..hist.ledger.rounds() {
        assert_eq!(hist.ledger.get(t).unwrap().senders, 5, "round {t}");
    }
}

#[test]
fn replaying_the_same_loopback_run_is_deterministic() {
    // Two full transport runs on the same seed (fresh sockets, fresh
    // fleet) replay bit-identically — arrival order genuinely does not
    // leak into the history.
    let run = base_run(
        Algorithm::CompressedGd {
            compressor: CompressorKind::Sign,
            aggregation: AggregationRule::MajorityVote,
        },
        4,
    );
    let h1 = loopback_vs_in_process(&run, 6, false, 2);
    let h2 = loopback_vs_in_process(&run, 6, false, 3);
    assert_identical(&h1, &h2);
}
