//! Zero-allocation contract for the per-round training hot path
//! (DESIGN.md §9): once a `ModelWorkspace` is warmed up, `loss_grad_ws`,
//! `evaluate_ws` and the full environment-level `sample_grad_ws` (batch
//! sampling + gather + forward/backward) must not touch the heap.
//!
//! Enforced with a counting global allocator. The counter is
//! **thread-local**, so concurrently running tests in this binary cannot
//! perturb the measurement taken on this thread. The whole-round
//! contract for the persistent pool engine (worker + server side, all
//! threads) lives in `tests/zero_alloc_round.rs`, which needs a global
//! counter and therefore its own binary.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use sparsignd::coordinator::{ClassifierEnv, GradientSource};
use sparsignd::data::{DirichletPartitioner, SyntheticSpec, SyntheticTask};
use sparsignd::model::{Mlp, Model, ModelKind, ModelWorkspace};
use sparsignd::util::rng::Pcg64;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

// SAFETY: defers all allocation to `System`; the thread-local counter is
// const-initialized (no lazy init, so no recursive allocation).
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        unsafe { System.alloc(layout) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs_on_this_thread() -> u64 {
    ALLOCS.with(|c| c.get())
}

/// Run `f` after two warm-up invocations and return how many heap
/// allocations the third performs on this thread.
fn steady_state_allocs(mut f: impl FnMut()) -> u64 {
    f();
    f();
    let before = allocs_on_this_thread();
    f();
    allocs_on_this_thread() - before
}

#[test]
fn mlp_loss_grad_steady_state_is_allocation_free() {
    // The paper's §C.2 architecture at the Table 1 batch size.
    let m = Mlp::new(784, vec![256, 128], 10);
    let mut rng = Pcg64::seed_from(1);
    let params = m.init(&mut rng);
    let batch = 64;
    let mut x = vec![0.0f32; batch * 784];
    rng.fill_normal(&mut x, 0.0, 1.0);
    let y: Vec<usize> = (0..batch).map(|i| i % 10).collect();
    let mut grad = vec![0.0f32; m.dim()];
    let mut ws = ModelWorkspace::new();

    let n = steady_state_allocs(|| {
        std::hint::black_box(m.loss_grad_ws(&params, &x, &y, &mut grad, &mut ws));
    });
    assert_eq!(n, 0, "loss_grad_ws allocated {n} times in steady state");

    let n = steady_state_allocs(|| {
        std::hint::black_box(m.evaluate_ws(&params, &x, &y, &mut ws));
    });
    assert_eq!(n, 0, "evaluate_ws allocated {n} times in steady state");
}

#[test]
fn env_sample_grad_steady_state_is_allocation_free() {
    // Full worker-side path: batch sampling + gather + forward/backward.
    let task = SyntheticTask::generate(
        SyntheticSpec {
            dim: 20,
            classes: 4,
            modes: 1,
            separation: 1.5,
            noise: 0.2,
            label_noise: 0.0,
            train: 400,
            test: 80,
        },
        7,
    );
    let mut rng = Pcg64::seed_from(8);
    let fed = DirichletPartitioner { alpha: 0.5, workers: 6 }.partition(&task.train, &mut rng);
    let env = ClassifierEnv::new(
        ModelKind::Mlp { inputs: 20, hidden: vec![16], classes: 4 }.build(),
        task.train,
        task.test,
        fed,
        16,
    );
    let params = env.init_params(&mut rng);
    let mut grad = vec![0.0f32; env.dim()];
    let mut ws = ModelWorkspace::new();
    let mut grng = Pcg64::seed_from(9);

    let n = steady_state_allocs(|| {
        std::hint::black_box(env.sample_grad_ws(2, &params, &mut grng, &mut grad, &mut ws));
    });
    assert_eq!(n, 0, "sample_grad_ws allocated {n} times in steady state");

    let n = steady_state_allocs(|| {
        std::hint::black_box(env.evaluate_ws(&params, &mut ws));
    });
    assert_eq!(n, 0, "ClassifierEnv::evaluate_ws allocated {n} times in steady state");
}
