//! Live observability plane, end to end (DESIGN.md §17): scrape a real
//! coordinator's `GET /metrics` over TCP while a loopback federation
//! runs, and pin the two contracts the plane makes:
//!
//! 1. **Bit-match** — at run end (during the post-`Fin` linger window)
//!    every scraped counter equals the corresponding `CommLedger` total
//!    in the returned `RunHistory`: same feed points, same numbers, no
//!    sampling.
//! 2. **Isolation** — hostile scrapers (oversized requests, half-open
//!    connections held across the whole run, a hammer loop) never stall
//!    a round: the run completes with *no* round deadline configured and
//!    its history stays bit-identical to the in-process engine.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

use sparsignd::compressors::CompressorKind;
use sparsignd::coordinator::{AggregationRule, Algorithm, ClassifierEnv, TrainingRun};
use sparsignd::data::{DirichletPartitioner, SyntheticSpec, SyntheticTask};
use sparsignd::metrics::registry::{parse_exposition, sample_value, Sample};
use sparsignd::model::ModelKind;
use sparsignd::net::{run_fleet, Endpoint, FleetOptions, NetCoordinator, ServeOptions};
use sparsignd::optim::LrSchedule;
use sparsignd::util::rng::Pcg64;

fn env(workers: usize) -> ClassifierEnv {
    let task = SyntheticTask::generate(
        SyntheticSpec {
            dim: 12,
            classes: 3,
            modes: 1,
            separation: 1.8,
            noise: 0.25,
            label_noise: 0.0,
            train: 480,
            test: 120,
        },
        41,
    );
    let mut rng = Pcg64::seed_from(42);
    let fed = DirichletPartitioner { alpha: 0.5, workers }.partition(&task.train, &mut rng);
    ClassifierEnv::new(
        ModelKind::Linear { inputs: 12, classes: 3 }.build(),
        task.train,
        task.test,
        fed,
        16,
    )
}

fn base_run(rounds: usize) -> TrainingRun {
    let mut run = TrainingRun::new(
        Algorithm::CompressedGd {
            compressor: CompressorKind::Sign,
            aggregation: AggregationRule::MajorityVote,
        },
        LrSchedule::Const { lr: 0.05 },
        rounds,
    );
    run.eval_every = 0;
    run.seed = 21;
    run
}

/// One blocking HTTP/1.0 GET. `Some(body)` on a 200, `None` on a closed
/// connection or non-200 — exactly what a scraper sees.
fn http_get(addr: &str, path: &str) -> Option<String> {
    let mut s = TcpStream::connect(addr).ok()?;
    s.set_read_timeout(Some(Duration::from_secs(10))).ok()?;
    s.write_all(format!("GET {path} HTTP/1.0\r\n\r\n").as_bytes()).ok()?;
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).ok()?;
    let text = String::from_utf8(buf).ok()?;
    let (head, body) = text.split_once("\r\n\r\n")?;
    head.starts_with("HTTP/1.0 200").then(|| body.to_string())
}

fn scrape(addr: &str) -> Vec<Sample> {
    let body = http_get(addr, "/metrics").expect("scrape answered");
    parse_exposition(&body).expect("exposition parses")
}

/// A serving coordinator with a scrape port on an ephemeral TCP port;
/// returns `(coordinator, dial endpoint, scrape "host:port")`.
fn bind_with_metrics(opts: ServeOptions) -> (NetCoordinator, Endpoint, String) {
    let coordinator = NetCoordinator::bind(
        opts.with_metrics_addr(Some(Endpoint::Tcp("127.0.0.1:0".into()))),
    )
    .expect("bind");
    let ep = coordinator.local_endpoint().clone();
    let scrape_addr = match coordinator.metrics_endpoint().expect("metrics bound") {
        Endpoint::Tcp(addr) => addr.clone(),
        #[cfg(unix)]
        other => panic!("expected a TCP scrape endpoint, got {other}"),
    };
    (coordinator, ep, scrape_addr)
}

#[test]
fn scraped_counters_bit_match_the_ledger_at_run_end() {
    let workers = 10;
    let rounds = 5;
    let e = env(workers);
    let run = base_run(rounds);
    let mut rng = Pcg64::seed_from(43);
    let init = e.init_params(&mut rng);

    let serve_opts = ServeOptions::new(Endpoint::Tcp("127.0.0.1:0".into()))
        .with_metrics_linger(Some(Duration::from_secs(3)));
    let (coordinator, ep, scrape_addr) = bind_with_metrics(serve_opts);
    let fleet_opts = FleetOptions::new().with_agents(3);

    let eval = |p: &[f32]| e.evaluate(p);
    let (hist, linger_samples) = std::thread::scope(|s| {
        let server = s.spawn(|| coordinator.serve(&run, workers, init, &eval));
        let fleet = s.spawn(|| run_fleet(&ep, &run, &e, &fleet_opts));
        fleet.join().expect("fleet thread").expect("fleet run");
        // The fleet saw Fin, so the coordinator is now inside its
        // linger window: totals are final and still scrape-able.
        assert_eq!(http_get(&scrape_addr, "/healthz").as_deref(), Some("ok\n"));
        assert_eq!(http_get(&scrape_addr, "/nope"), None, "unknown path gets no response");
        let samples = scrape(&scrape_addr);
        (server.join().expect("server thread").expect("serve"), samples)
    });

    let root = [("role", "root")];
    let get = |name: &str| sample_value(&linger_samples, name, &root);
    assert_eq!(get("sparsignd_rounds_closed_total"), Some(rounds as u64));
    assert_eq!(get("sparsignd_round_phase"), Some(4), "FINISHED during linger");
    assert_eq!(
        get("sparsignd_uplink_wire_bytes_total"),
        Some(hist.ledger.total_uplink_wire_bytes())
    );
    assert_eq!(
        get("sparsignd_downlink_wire_bytes_total"),
        Some(hist.ledger.total_downlink_wire_bytes())
    );
    assert_eq!(
        get("sparsignd_stragglers_total"),
        Some(hist.ledger.total_stragglers() as u64)
    );
    assert_eq!(
        get("sparsignd_shard_uplink_wire_bytes_total"),
        Some(hist.ledger.total_shard_uplink_wire_bytes())
    );
    assert!(hist.ledger.total_uplink_wire_bytes() > 0, "a real run moved real bytes");
    // Reject counters: one labelled sample per kind, each equal to the
    // ledger's typed counter (all zero on an honest run — equality is
    // the contract either way).
    let kinds = ["bad_round", "not_selected", "duplicate", "late", "unknown_worker", "wrong_client"];
    for (i, kind) in kinds.iter().enumerate() {
        assert_eq!(
            sample_value(
                &linger_samples,
                "sparsignd_rejects_total",
                &[("role", "root"), ("kind", kind)],
            ),
            Some(hist.ledger.rejects_by_kind()[i]),
            "kind {kind}"
        );
    }
}

#[test]
fn hostile_scrapers_never_stall_a_round() {
    let workers = 8;
    let rounds = 4;
    let e = env(workers);
    let run = base_run(rounds);
    let mut rng = Pcg64::seed_from(44);
    let init = e.init_params(&mut rng);
    // The in-process reference this hammered run must still bit-match.
    let expected = run.run(&e, init.clone(), &|p| e.evaluate(p));

    // No round deadline: if a slow or malicious scraper could stall the
    // reactor, this run would simply hang (and the test harness would
    // time out) — completing at all is the isolation proof.
    let serve_opts = ServeOptions::new(Endpoint::Tcp("127.0.0.1:0".into()))
        .with_metrics_linger(Some(Duration::from_millis(500)));
    let (coordinator, ep, scrape_addr) = bind_with_metrics(serve_opts);
    let fleet_opts = FleetOptions::new().with_agents(2);

    // Half-open connection held across the entire run: connects, never
    // sends a byte, never reads.
    let half_open = TcpStream::connect(&scrape_addr).expect("half-open connect");

    // Oversized request: blows the request cap, gets the connection
    // dropped with no response bytes ever written.
    let mut oversized = TcpStream::connect(&scrape_addr).expect("oversized connect");
    oversized.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let _ = oversized.write_all(&[b'A'; 4096]);
    let mut got = Vec::new();
    let _ = oversized.read_to_end(&mut got);
    assert!(got.is_empty(), "hostile request must get no response, got {} bytes", got.len());

    let stop = AtomicBool::new(false);
    let eval = |p: &[f32]| e.evaluate(p);
    let hist = std::thread::scope(|s| {
        let server = s.spawn(|| coordinator.serve(&run, workers, init, &eval));
        // Hammer loop: full scrapes as fast as the responder answers,
        // for the whole duration of the run.
        let hammer = s.spawn(|| {
            let mut ok = 0usize;
            while !stop.load(Ordering::Relaxed) {
                if http_get(&scrape_addr, "/metrics").is_some() {
                    ok += 1;
                }
            }
            ok
        });
        let fleet = s.spawn(|| run_fleet(&ep, &run, &e, &fleet_opts));
        fleet.join().expect("fleet thread").expect("fleet run");
        stop.store(true, Ordering::Relaxed);
        let scrapes = hammer.join().expect("hammer thread");
        assert!(scrapes > 0, "the hammer loop must have landed real scrapes");
        server.join().expect("server thread").expect("serve")
    });
    drop(half_open);

    // A good scrape still works after the hostile ones were dropped
    // (checked above via the hammer loop), and the protocol outcome is
    // untouched by any of it.
    assert_eq!(expected.final_params, hist.final_params, "history bit-identical under hammering");
    assert_eq!(expected.reports.len(), hist.reports.len());
    assert_eq!(hist.ledger.total_stragglers(), 0, "no round closed short");
}
