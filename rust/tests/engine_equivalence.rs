//! Parallel round-engine equivalence: for every `Algorithm` variant, a run
//! fanned out over the persistent pool engine must produce a `RunHistory`
//! that is **bit-identical** to the serial reference (`threads = Some(1)`)
//! — losses, per-round uplink/downlink bits, and final parameters. This is
//! the determinism contract the engine's worker fan-out is built on:
//! worker `m` at round `t` draws from `root.derive(t‖m)` regardless of
//! which thread executes it, order-sensitive scalars are reduced from
//! index-addressed slots in selection order, and on the streaming fast
//! path the per-thread vote accumulators hold exact integers, so their
//! merge order cannot change the counts (DESIGN.md §10). The algorithm
//! list covers both pool routes: streaming (unit-scale packed ternary,
//! with MajorityVote and ScaledSign finalizes) and buffered
//! (EF-sparsign's server residual, FedAvg/FedCom deltas, and TernGrad's
//! per-message scales).

use sparsignd::compressors::CompressorKind;
use sparsignd::coordinator::{
    AggregationRule, Algorithm, Attack, AttackPlan, ClassifierEnv, RunHistory,
    TrainingRun,
};
use sparsignd::data::{DirichletPartitioner, SyntheticSpec, SyntheticTask};
use sparsignd::model::ModelKind;
use sparsignd::optim::LrSchedule;
use sparsignd::util::rng::Pcg64;

fn env(workers: usize) -> ClassifierEnv {
    let task = SyntheticTask::generate(
        SyntheticSpec {
            dim: 12,
            classes: 3,
            modes: 1,
            separation: 1.6,
            noise: 0.25,
            label_noise: 0.0,
            train: 480,
            test: 120,
        },
        31,
    );
    let mut rng = Pcg64::seed_from(32);
    let fed = DirichletPartitioner { alpha: 0.3, workers }.partition(&task.train, &mut rng);
    ClassifierEnv::new(
        ModelKind::Linear { inputs: 12, classes: 3 }.build(),
        task.train,
        task.test,
        fed,
        16,
    )
}

fn run_with_threads(
    e: &ClassifierEnv,
    alg: Algorithm,
    participation: f64,
    attack: Option<AttackPlan>,
    threads: Option<usize>,
) -> RunHistory {
    let run = TrainingRun {
        algorithm: alg,
        schedule: LrSchedule::Const { lr: 0.03 },
        rounds: 15,
        participation,
        eval_every: 4,
        seed: 77,
        attack,
        selection: Default::default(),
        allow_stateful_with_sampling: false,
        threads,
    };
    let mut init_rng = Pcg64::seed_from(78);
    let init = e.init_params(&mut init_rng);
    run.run(e, init, &|p| e.evaluate(p))
}

/// Field-by-field bit equality of two run histories.
fn assert_identical(a: &RunHistory, b: &RunHistory, label: &str) {
    assert_eq!(a.final_params, b.final_params, "{label}: final params differ");
    assert_eq!(a.reports.len(), b.reports.len(), "{label}");
    assert_eq!(
        a.ledger.total_uplink_nnz(),
        b.ledger.total_uplink_nnz(),
        "{label}: ledger nnz differ"
    );
    assert_eq!(a.ledger.total_uplink(), b.ledger.total_uplink(), "{label}");
    for (ra, rb) in a.reports.iter().zip(&b.reports) {
        assert_eq!(ra.round, rb.round, "{label}");
        assert_eq!(ra.lr, rb.lr, "{label} round {}", ra.round);
        assert_eq!(ra.train_loss, rb.train_loss, "{label} round {}", ra.round);
        assert_eq!(ra.eval, rb.eval, "{label} round {}", ra.round);
        assert_eq!(ra.uplink_bits, rb.uplink_bits, "{label} round {}", ra.round);
        assert_eq!(ra.downlink_bits, rb.downlink_bits, "{label} round {}", ra.round);
        assert_eq!(
            ra.cum_uplink_bits, rb.cum_uplink_bits,
            "{label} round {}",
            ra.round
        );
    }
}

fn all_algorithms() -> Vec<Algorithm> {
    vec![
        Algorithm::CompressedGd {
            compressor: CompressorKind::Sparsign { budget: 0.5 },
            aggregation: AggregationRule::MajorityVote,
        },
        // Streaming route with the scaled-sign finalize (f64 ℓ1).
        Algorithm::CompressedGd {
            compressor: CompressorKind::Sign,
            aggregation: AggregationRule::ScaledSign,
        },
        // Per-message scales defeat the streaming predicate: exercises
        // the pool's buffered route for CompressedGd.
        Algorithm::CompressedGd {
            compressor: CompressorKind::TernGrad,
            aggregation: AggregationRule::Mean,
        },
        Algorithm::EfSparsign {
            b_local: 10.0,
            b_global: 1.0,
            tau: 2,
            server_lr_scale: None,
            server_ef: true,
        },
        Algorithm::FedAvg { tau: 2 },
        Algorithm::FedCom { tau: 2, levels: 255 },
    ]
}

#[test]
fn threaded_runs_are_bit_identical_to_serial() {
    let e = env(12);
    for alg in all_algorithms() {
        let label = alg.label();
        let serial = run_with_threads(&e, alg.clone(), 1.0, None, Some(1));
        for threads in [2, 4, 7] {
            let par = run_with_threads(&e, alg.clone(), 1.0, None, Some(threads));
            assert_identical(&serial, &par, &format!("{label} (threads={threads})"));
        }
        // Auto width (available_parallelism) must match too.
        let auto = run_with_threads(&e, alg.clone(), 1.0, None, None);
        assert_identical(&serial, &auto, &format!("{label} (threads=auto)"));
    }
}

#[test]
fn equivalence_holds_under_partial_participation() {
    let e = env(12);
    for alg in all_algorithms() {
        let label = alg.label();
        let serial = run_with_threads(&e, alg.clone(), 0.5, None, Some(1));
        let par = run_with_threads(&e, alg.clone(), 0.5, None, Some(3));
        assert_identical(&serial, &par, &format!("{label} @ p_s=0.5"));
    }
}

#[test]
fn equivalence_holds_under_attack() {
    let e = env(12);
    let attack = Some(AttackPlan::new(Attack::Rescale { factor: 100.0 }, 3));
    let alg = Algorithm::CompressedGd {
        compressor: CompressorKind::Sparsign { budget: 1.0 },
        aggregation: AggregationRule::MajorityVote,
    };
    let serial = run_with_threads(&e, alg.clone(), 1.0, attack.clone(), Some(1));
    let par = run_with_threads(&e, alg, 1.0, attack, Some(4));
    assert_identical(&serial, &par, "sparsign under rescale attack");
}

#[test]
fn equivalence_holds_for_stateful_compressor_at_full_participation() {
    // Worker-EF keeps per-worker residuals; with full participation each
    // worker's state advances once per round on whichever thread owns it,
    // so threading must not change the trajectory.
    let e = env(8);
    let alg = Algorithm::CompressedGd {
        compressor: CompressorKind::WorkerEf(Box::new(CompressorKind::ScaledSign)),
        aggregation: AggregationRule::ScaledSign,
    };
    let serial = run_with_threads(&e, alg.clone(), 1.0, None, Some(1));
    let par = run_with_threads(&e, alg, 1.0, None, Some(3));
    assert_identical(&serial, &par, "worker-EF scaled-sign");
}

#[test]
fn mlp_workspace_path_is_bit_identical_across_threads() {
    // The per-thread `ModelWorkspace` (activations, deltas, GEMM packing
    // buffers, batch gather scratch) must not leak any state between the
    // workers that share a thread: an MLP-backed run — the configuration
    // that actually exercises the packed-GEMM workspace hot path — has to
    // replay bit-identically at every fan-out width.
    let task = SyntheticTask::generate(
        SyntheticSpec {
            dim: 12,
            classes: 3,
            modes: 1,
            separation: 1.6,
            noise: 0.25,
            label_noise: 0.0,
            train: 480,
            test: 120,
        },
        33,
    );
    let mut rng = Pcg64::seed_from(34);
    let fed = DirichletPartitioner { alpha: 0.3, workers: 10 }.partition(&task.train, &mut rng);
    let e = ClassifierEnv::new(
        ModelKind::Mlp { inputs: 12, hidden: vec![17, 9], classes: 3 }.build(),
        task.train,
        task.test,
        fed,
        16,
    );
    for alg in [
        Algorithm::CompressedGd {
            compressor: CompressorKind::Sparsign { budget: 0.5 },
            aggregation: AggregationRule::MajorityVote,
        },
        Algorithm::EfSparsign {
            b_local: 10.0,
            b_global: 1.0,
            tau: 2,
            server_lr_scale: None,
            server_ef: true,
        },
    ] {
        let label = format!("mlp-workspace {}", alg.label());
        let serial = run_with_threads(&e, alg.clone(), 0.8, None, Some(1));
        for threads in [2, 5] {
            let par = run_with_threads(&e, alg.clone(), 0.8, None, Some(threads));
            assert_identical(&serial, &par, &format!("{label} (threads={threads})"));
        }
    }
}

#[test]
fn thread_count_larger_than_worker_pool_is_safe() {
    let e = env(3);
    let alg = Algorithm::CompressedGd {
        compressor: CompressorKind::Sign,
        aggregation: AggregationRule::MajorityVote,
    };
    let serial = run_with_threads(&e, alg.clone(), 1.0, None, Some(1));
    let par = run_with_threads(&e, alg, 1.0, None, Some(64));
    assert_identical(&serial, &par, "threads > workers");
}
