//! Property-based suite over the crate's core invariants (DESIGN.md §7),
//! using the in-tree `testing` mini-framework.

use sparsignd::coding::golomb;
use sparsignd::compressors::{
    CompressedGrad, Compressor, CompressorKind, NormKind, PackedTernary,
};
use sparsignd::coordinator::{vote_counts, AggregationRule, VoteAccumulator};
use sparsignd::experiments::theory;
use sparsignd::testing::{check, check_vec, gen, PropConfig};
use sparsignd::util::rng::Pcg64;

fn cfg(cases: usize, seed: u64) -> PropConfig {
    PropConfig { cases, seed }
}

/// Every compressor: ternary payloads really are ternary, nnz counts are
/// consistent, and bit accounting is non-negative and finite.
#[test]
fn prop_all_compressors_well_formed() {
    let kinds = [
        CompressorKind::Sign,
        CompressorKind::ScaledSign,
        CompressorKind::NoisySign { noise_std: 0.05 },
        CompressorKind::Qsgd { levels: 1, norm: NormKind::L2 },
        CompressorKind::Qsgd { levels: 4, norm: NormKind::Linf },
        CompressorKind::TernGrad,
        CompressorKind::Sparsign { budget: 0.5 },
        CompressorKind::TopK { k: 7 },
        CompressorKind::RandK { k: 7 },
        CompressorKind::ThresholdV { v: 0.05 },
        CompressorKind::Stc { k: 7 },
        CompressorKind::Identity,
    ];
    for kind in kinds {
        let label = kind.label();
        check_vec(
            cfg(48, 0x11),
            (1, 300),
            gen::f32_gradient_like(),
            |g| {
                let mut comp = kind.build(g.len());
                let mut rng = Pcg64::seed_from(1);
                let msg = comp.compress(g, &mut rng);
                if msg.dim() != g.len() {
                    return Err(format!("{label}: dim {} != {}", msg.dim(), g.len()));
                }
                if !(msg.bits() >= 0.0 && msg.bits().is_finite()) {
                    return Err(format!("{label}: bad bits {}", msg.bits()));
                }
                if let CompressedGrad::Ternary { pack, .. } = &msg {
                    let codes = pack.to_codes();
                    if !codes.iter().all(|&x| (-1..=1).contains(&x)) {
                        return Err(format!("{label}: non-ternary code"));
                    }
                    if !pack.scale().is_finite() {
                        return Err(format!("{label}: bad scale {}", pack.scale()));
                    }
                    let counted = codes.iter().filter(|&&x| x != 0).count();
                    if counted != pack.nnz() {
                        return Err(format!(
                            "{label}: cached nnz {} != recount {counted}",
                            pack.nnz()
                        ));
                    }
                }
                if msg.nnz() > g.len() {
                    return Err(format!("{label}: nnz > d"));
                }
                Ok(())
            },
        );
    }
}

/// sparsign expected density: |nnz − E[nnz]| stays within 6σ across
/// random gradients and budgets.
#[test]
fn prop_sparsign_density_matches_definition() {
    check(
        cfg(40, 0x22),
        |rng| {
            let n = 200 + rng.index(2_000);
            let budget = rng.range_f32(0.01, 3.0);
            let mut g = vec![0.0f32; n];
            rng.fill_normal(&mut g, 0.0, 0.5);
            (g, budget)
        },
        |(g, budget)| {
            let comp = sparsignd::compressors::SparsignCompressor { budget: *budget };
            let expect = comp.expected_nnz(g);
            // Average over 32 draws.
            let mut c = comp;
            let mut rng = Pcg64::seed_from(7);
            let reps = 32;
            let total: usize = (0..reps).map(|_| c.compress(g, &mut rng).nnz()).sum();
            let got = total as f64 / reps as f64;
            let sigma = (expect.max(1.0) / reps as f64).sqrt() * 2.0;
            if (got - expect).abs() <= 6.0 * sigma + 1.0 {
                Ok(())
            } else {
                Err(format!("nnz {got:.1} vs E {expect:.1} (σ≈{sigma:.2})"))
            }
        },
    );
}

/// Golomb: decode ∘ encode = identity for arbitrary sparse supports.
#[test]
fn prop_golomb_roundtrip() {
    check(
        cfg(128, 0x33),
        |rng| {
            let d = 1 + rng.index(50_000);
            let p = rng.f64() * 0.6;
            let idx: Vec<usize> = (0..d).filter(|_| rng.bernoulli(p)).collect();
            (idx, d)
        },
        |(idx, d)| {
            let (bytes, bits) = golomb::encode_indices(idx, *d);
            if bits > bytes.len() * 8 {
                return Err("bit count exceeds buffer".into());
            }
            match golomb::decode_indices(&bytes) {
                Some(out) if &out == idx => Ok(()),
                Some(_) => Err("roundtrip mismatch".into()),
                None => Err("decode failed".into()),
            }
        },
    );
}

/// Aggregation is permutation-invariant in the worker order.
#[test]
fn prop_aggregation_permutation_invariant() {
    check(
        cfg(64, 0x44),
        |rng| {
            let d = 1 + rng.index(64);
            let m = 2 + rng.index(12);
            let msgs: Vec<CompressedGrad> = (0..m)
                .map(|_| {
                    let q: Vec<i8> =
                        (0..d).map(|_| [-1i8, 0, 1][rng.index(3)]).collect();
                    CompressedGrad::ternary_from_codes(&q, rng.range_f32(0.1, 2.0), 0.0)
                })
                .collect();
            let mut shuffled = msgs.clone();
            rng.shuffle(&mut shuffled);
            (msgs, shuffled)
        },
        |(a, b)| {
            for rule in [
                AggregationRule::MajorityVote,
                AggregationRule::ScaledSign,
                AggregationRule::Mean,
            ] {
                let ua = rule.aggregate(a, None).update;
                let ub = rule.aggregate(b, None).update;
                for (x, y) in ua.iter().zip(&ub) {
                    if (x - y).abs() > 1e-5 {
                        return Err(format!("{rule:?} not permutation-invariant"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Theorem 1: the closed-form bound dominates Monte-Carlo estimates for
/// random adversarial scalar populations (not just the eq. (11) one).
#[test]
fn prop_theorem1_bound_dominates() {
    check(
        cfg(20, 0x55),
        |rng| {
            let m = 20 + rng.index(100);
            let negs = rng.index(m * 8 / 10);
            let budget = 0.05 + rng.f64() * 0.4;
            (m, negs, budget, rng.next_u64())
        },
        |&(m, negs, budget, seed)| {
            let mut rng = Pcg64::seed_from(seed);
            // Positive-sum population with `negs` sign-flipped members.
            let mut u = vec![0.0f64; m];
            let mut neg_sum = 0.0;
            for v in u.iter_mut().take(negs) {
                let mag = 0.2 + 0.3 * rng.f64();
                *v = -mag;
                neg_sum += mag;
            }
            let pos = m - negs;
            for v in u.iter_mut().skip(negs) {
                *v = (1.0 + neg_sum) / pos as f64;
            }
            let (p_bar, q_bar) = theory::corollary1_rates(&u, budget, 1.0);
            if q_bar <= p_bar {
                return Ok(()); // Theorem 1 precondition not met; skip
            }
            let emp = theory::empirical_wrong_aggregation(&u, budget, 1.0, 3_000, &mut rng);
            let bound = theory::theorem1_bound(p_bar, q_bar, m);
            if emp <= bound + 0.03 {
                Ok(())
            } else {
                Err(format!("empirical {emp:.4} > bound {bound:.4} (M={m}, B={budget:.2})"))
            }
        },
    );
}

/// Scaled-sign aggregation is α-approximate: ‖C(x) − x‖² ≤ (1−α)‖x‖² with
/// α = ‖x‖₁²/(d‖x‖₂²) — the Algorithm 2 server-compressor contract.
#[test]
fn prop_scaled_sign_alpha_approximate() {
    check_vec(
        cfg(96, 0x66),
        (1, 512),
        gen::f32_normal(2.0),
        |x| {
            let msgs = [CompressedGrad::dense(x.to_vec(), 0.0)];
            let c = AggregationRule::ScaledSign.aggregate(&msgs, None).update;
            let err: f64 = c
                .iter()
                .zip(x)
                .map(|(a, b)| ((a - b) * (a - b)) as f64)
                .sum();
            let l1: f64 = x.iter().map(|v| v.abs() as f64).sum();
            let l2sq: f64 = x.iter().map(|v| (v * v) as f64).sum();
            if l2sq == 0.0 {
                return Ok(());
            }
            let alpha = l1 * l1 / (x.len() as f64 * l2sq);
            if err <= (1.0 - alpha) * l2sq + 1e-3 {
                Ok(())
            } else {
                Err(format!("err {err} > (1-α)‖x‖² = {}", (1.0 - alpha) * l2sq))
            }
        },
    );
}

/// Unbiased compressors (TernGrad, 1-bit QSGD, Random-k): empirical mean
/// of the decoded message approaches the gradient.
#[test]
fn prop_unbiased_compressors_are_unbiased() {
    for kind in [
        CompressorKind::TernGrad,
        CompressorKind::Qsgd { levels: 1, norm: NormKind::L2 },
        CompressorKind::RandK { k: 8 },
    ] {
        let label = kind.label();
        check(
            cfg(12, 0x77),
            |rng| {
                let n = 16 + rng.index(48);
                let mut g = vec![0.0f32; n];
                rng.fill_normal(&mut g, 0.0, 1.0);
                g
            },
            |g| {
                let mut comp = kind.build(g.len());
                let mut rng = Pcg64::seed_from(11);
                let reps = 3_000;
                let mut mean = vec![0.0f64; g.len()];
                for _ in 0..reps {
                    for (m, v) in mean.iter_mut().zip(comp.compress(g, &mut rng).to_dense()) {
                        *m += v as f64;
                    }
                }
                let scale = 1.0 / reps as f64;
                for (i, (m, &gi)) in mean.iter().zip(g.iter()).enumerate() {
                    let est = m * scale;
                    // 6σ-ish tolerance: variance per draw is O(‖g‖·d) for
                    // these compressors; use a generous absolute band.
                    let tol = 0.3 + 0.1 * gi.abs() as f64
                        + 6.0 * (g.len() as f64).sqrt() / (reps as f64).sqrt();
                    if (est - gi as f64).abs() > tol {
                        return Err(format!(
                            "{label} coord {i}: E[Q] {est:.3} vs g {gi:.3}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}

/// Packed ternary bitplanes: `from_codes ∘ to_codes = id`, cached nnz is
/// exact, random access agrees, and `add_into` matches the scalar decode —
/// across dimensions that straddle word boundaries.
#[test]
fn prop_packed_ternary_roundtrip() {
    check(
        cfg(128, 0x88),
        |rng| {
            let d = rng.index(520); // covers 0, sub-word, and multi-word dims
            let scale = rng.range_f32(0.1, 4.0);
            let q: Vec<i8> = (0..d).map(|_| [-1i8, 0, 1][rng.index(3)]).collect();
            (q, scale)
        },
        |(q, scale)| {
            let pack = PackedTernary::from_codes(q, *scale);
            if pack.to_codes() != *q {
                return Err("to_codes roundtrip mismatch".into());
            }
            let want_nnz = q.iter().filter(|&&x| x != 0).count();
            if pack.nnz() != want_nnz {
                return Err(format!("nnz {} vs {}", pack.nnz(), want_nnz));
            }
            for (i, &c) in q.iter().enumerate() {
                if pack.get(i) != c {
                    return Err(format!("get({i}) = {} vs {c}", pack.get(i)));
                }
            }
            let mut fast = vec![0.0f32; q.len()];
            pack.add_into(&mut fast);
            for (i, (&f, &c)) in fast.iter().zip(q.iter()).enumerate() {
                if f != scale * c as f32 {
                    return Err(format!("add_into coord {i}: {f} vs {}", scale * c as f32));
                }
            }
            Ok(())
        },
    );
}

/// The word-parallel vote kernel equals the naive per-coordinate sum for
/// arbitrary message sets, including message counts that cross the
/// vertical-counter plane boundaries (1, 2, 3, 4, … planes).
#[test]
fn prop_vote_counts_equal_naive() {
    check(
        cfg(64, 0x99),
        |rng| {
            let d = 1 + rng.index(400);
            let m = 1 + rng.index(70);
            let codes: Vec<Vec<i8>> = (0..m)
                .map(|_| (0..d).map(|_| [-1i8, -1, 0, 0, 0, 1][rng.index(6)]).collect())
                .collect();
            codes
        },
        |codes| {
            let d = codes[0].len();
            let packs: Vec<PackedTernary> =
                codes.iter().map(|q| PackedTernary::from_codes(q, 1.0)).collect();
            let refs: Vec<&PackedTernary> = packs.iter().collect();
            let counts = vote_counts(&refs, d);
            for i in 0..d {
                let want: i32 = codes.iter().map(|q| q[i] as i32).sum();
                if counts[i] as i32 != want {
                    return Err(format!("coord {i}: {} vs {want}", counts[i]));
                }
            }
            Ok(())
        },
    );
}

/// Streaming determinism (DESIGN.md §10): sharding an arbitrary message
/// multiset over any number of per-thread `VoteAccumulator`s — arbitrary
/// assignment, including empty shards — and merging must reproduce the
/// single-shot `vote_counts` exactly. Message counts range past 255 so
/// the accumulators cross the 8-plane word-transpose group boundary.
#[test]
fn prop_vote_accumulator_merge_matches_single_shot() {
    check(
        cfg(48, 0xbb),
        |rng| {
            let d = 1 + rng.index(260);
            let m = 1 + rng.index(520);
            let shards = 1 + rng.index(8);
            let codes: Vec<Vec<i8>> = (0..m)
                .map(|_| (0..d).map(|_| [-1i8, -1, 0, 0, 1, 1][rng.index(6)]).collect())
                .collect();
            let assign: Vec<usize> = (0..m).map(|_| rng.index(shards)).collect();
            (codes, assign, shards)
        },
        |(codes, assign, shards)| {
            let d = codes[0].len();
            let m = codes.len();
            let packs: Vec<PackedTernary> =
                codes.iter().map(|q| PackedTernary::from_codes(q, 1.0)).collect();
            let refs: Vec<&PackedTernary> = packs.iter().collect();
            let want = vote_counts(&refs, d);
            let mut global = VoteAccumulator::new();
            global.reset(d, m);
            let mut local = VoteAccumulator::new();
            for s in 0..*shards {
                local.reset(d, m);
                for (pack, &owner) in packs.iter().zip(assign) {
                    if owner == s {
                        local.fold(pack);
                    }
                }
                global.merge(&local);
            }
            if global.msgs() != m {
                return Err(format!("merged {} of {m} messages", global.msgs()));
            }
            let mut got = vec![0i16; d];
            global.counts_into(&mut got);
            for i in 0..d {
                if got[i] != want[i] {
                    return Err(format!(
                        "coord {i} (d={d}, m={m}, shards={shards}): merged {} vs \
                         single-shot {}",
                        got[i],
                        want[i]
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Aggregating uniform-scale packed messages (the word-parallel fast path)
/// must agree exactly with a message set decoded to dense f32 first (the
/// fallback path) for every rule.
#[test]
fn prop_packed_aggregation_matches_dense_decode() {
    check(
        cfg(48, 0xaa),
        |rng| {
            let d = 1 + rng.index(300);
            let m = 1 + rng.index(20);
            let codes: Vec<Vec<i8>> = (0..m)
                .map(|_| (0..d).map(|_| [-1i8, 0, 1][rng.index(3)]).collect())
                .collect();
            codes
        },
        |codes| {
            let d = codes[0].len();
            let m = codes.len();
            let packed: Vec<CompressedGrad> = codes
                .iter()
                .map(|q| CompressedGrad::ternary_from_codes(q, 1.0, 0.0))
                .collect();
            // Dense f32 decode forces the fallback path.
            let dense: Vec<CompressedGrad> = codes
                .iter()
                .map(|q| {
                    let v: Vec<f32> = q.iter().map(|&c| c as f32).collect();
                    CompressedGrad::dense(v, 0.0)
                })
                .collect();
            for rule in [
                AggregationRule::MajorityVote,
                AggregationRule::ScaledSign,
                AggregationRule::Mean,
            ] {
                let a = rule.aggregate(&packed, None).update;
                let b = rule.aggregate(&dense, None).update;
                for i in 0..d {
                    if (a[i] - b[i]).abs() > 1e-6 {
                        return Err(format!(
                            "{rule:?} coord {i} (d={d}, m={m}): packed {} vs dense {}",
                            a[i], b[i]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Wire-codec hardening (DESIGN.md §11): random messages round-trip
// bit-identically, and mutated/truncated/hostile byte streams always
// come back as typed `WireError`s — never a panic, never an unchecked
// allocation.
// ---------------------------------------------------------------------

use sparsignd::net::wire::{self, Msg, RejectReason, WireBuf, WireError};
use sparsignd::net::NetError;

/// Random protocol message (every variant, random payload shapes).
fn gen_wire_msg(rng: &mut Pcg64) -> Msg {
    let grad = |rng: &mut Pcg64| {
        let d = 1 + rng.index(300);
        if rng.bernoulli(0.5) {
            let codes: Vec<i8> = (0..d).map(|_| [-1i8, 0, 0, 1][rng.index(4)]).collect();
            let scale = if rng.bernoulli(0.5) { 1.0 } else { rng.f32() + 0.25 };
            CompressedGrad::ternary_from_codes(&codes, scale, rng.f64() * 1e4)
        } else {
            let v: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            CompressedGrad::dense(v, rng.f64() * 1e4)
        }
    };
    match rng.index(8) {
        0 => Msg::Hello {
            lo: rng.next_u64() >> 40,
            hi: rng.next_u64() >> 40,
            cfg: rng.next_u64(),
            env: rng.next_u64(),
        },
        1 => Msg::Welcome {
            client_id: rng.next_u64() >> 32,
            workers: rng.next_u64() >> 32,
            dim: rng.next_u64() >> 32,
            rounds: rng.next_u64() >> 32,
            commit: [rng.next_u64(), rng.next_u64(), rng.next_u64(), rng.next_u64()],
        },
        2 => {
            let k = rng.index(20);
            let d = rng.index(200);
            Msg::RoundOpen {
                t: rng.next_u64() >> 40,
                lr: rng.f64(),
                deadline_ms: rng.next_u64() >> 48,
                selected: (0..k).map(|_| rng.next_u64() >> 40).collect(),
                params: (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
            }
        }
        3 => Msg::Update {
            t: rng.next_u64() >> 40,
            worker: rng.next_u64() >> 40,
            loss: rng.f64(),
            grad: grad(rng),
        },
        4 => Msg::Ack { t: rng.next_u64() >> 40, worker: rng.next_u64() >> 40 },
        5 => Msg::Reject {
            t: rng.next_u64() >> 40,
            worker: rng.next_u64() >> 40,
            reason: [
                RejectReason::BadRound,
                RejectReason::NotSelected,
                RejectReason::Duplicate,
                RejectReason::Late,
                RejectReason::UnknownWorker,
                RejectReason::WrongClient,
            ][rng.index(6)],
        },
        6 => Msg::Fin { rounds: rng.next_u64() >> 40 },
        _ => Msg::Heartbeat { client_id: rng.next_u64() >> 40 },
    }
}

#[test]
fn prop_wire_roundtrip_bit_identical() {
    check(cfg(96, 0x171), gen_wire_msg, |msg| {
        let mut wbuf = WireBuf::new();
        let mut out = Vec::new();
        let n = wbuf.encode(msg, &mut out);
        if n != out.len() {
            return Err(format!("encode reported {n}, wrote {}", out.len()));
        }
        let (frame, used) = wire::parse_frame(&out, wire::MAX_PAYLOAD)
            .map_err(|e| format!("parse: {e}"))?;
        if used != n {
            return Err(format!("consumed {used} of {n}"));
        }
        let back = wire::decode_msg(frame).map_err(|e| format!("decode: {e}"))?;
        if &back != msg {
            return Err(format!("roundtrip mismatch: {back:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_wire_single_byte_mutations_yield_typed_errors() {
    check(
        cfg(128, 0x172),
        |rng| {
            let msg = gen_wire_msg(rng);
            let mut wbuf = WireBuf::new();
            let mut out = Vec::new();
            wbuf.encode(&msg, &mut out);
            let at = rng.index(out.len());
            let flip = 1 + rng.index(255) as u8;
            (out, at, flip)
        },
        |case| {
            let (frame, at, flip) = case;
            let mut bad = frame.clone();
            bad[*at] ^= *flip;
            // Any single-byte corruption must surface as a typed error:
            // the header checks catch the first six bytes, CRC-32 catches
            // every ≤32-bit burst in the body, and a corrupted length
            // varint lands on Truncated/Oversized/BadCrc.
            match wire::parse_frame(&bad, wire::MAX_PAYLOAD) {
                Err(_) => Ok(()),
                Ok((f, _)) => match wire::decode_msg(f) {
                    Err(_) => Ok(()),
                    Ok(m) => Err(format!("mutation at {at} (^{flip:#x}) decoded: {m:?}")),
                },
            }
        },
    );
}

#[test]
fn prop_wire_truncations_yield_typed_errors() {
    check(
        cfg(64, 0x173),
        |rng| {
            let msg = gen_wire_msg(rng);
            let mut wbuf = WireBuf::new();
            let mut out = Vec::new();
            wbuf.encode(&msg, &mut out);
            let cut = rng.index(out.len());
            (out, cut)
        },
        |case| {
            let (frame, cut) = case;
            match wire::parse_frame(&frame[..*cut], wire::MAX_PAYLOAD) {
                Err(WireError::Truncated { .. }) => Ok(()),
                Err(other) => Err(format!("cut {cut}: wrong error {other}")),
                Ok(_) => Err(format!("cut {cut}: parsed a prefix")),
            }
        },
    );
}

// ---------------------------------------------------------------------
// Snapshot-codec hardening (DESIGN.md §12): random coordinator states
// round-trip bit-identically; mutated/truncated/version-bumped files are
// typed `SnapshotError`s — never a panic, never an attacker-length
// allocation; and a golden re-encoding pins the version-1 layout.
// ---------------------------------------------------------------------

use sparsignd::coordinator::{CommLedger, RoundComm, RoundReport, SelectionSnapshot};
use sparsignd::snapshot::{
    CoordinatorSnapshot, SnapPhase, SnapshotError, KIND_COORDINATOR, SNAP_MAGIC, SNAP_VERSION,
};
use sparsignd::util::rng::{selection_commitment, selection_root_key};

/// Random-but-internally-consistent coordinator snapshot.
fn gen_snapshot(rng: &mut Pcg64) -> CoordinatorSnapshot {
    let dim = 1 + rng.index(150);
    let rounds_total = 1 + rng.index(10);
    let next = rng.index(rounds_total + 1);
    let reports: Vec<RoundReport> = (0..next)
        .map(|t| RoundReport {
            round: t,
            lr: rng.f64(),
            train_loss: rng.normal(),
            eval: rng.bernoulli(0.5).then(|| (rng.normal(), rng.f64())),
            uplink_bits: rng.f64() * 1e6,
            downlink_bits: rng.f64() * 1e4,
            cum_uplink_bits: rng.f64() * 1e7,
        })
        .collect();
    let mut ledger = CommLedger::new();
    for _ in 0..next {
        ledger.record(RoundComm {
            uplink_bits: rng.f64() * 1e6,
            downlink_bits: rng.f64() * 1e4,
            senders: rng.index(500),
            uplink_nnz: rng.index(1 << 20),
            uplink_wire_bytes: rng.next_u64() >> 40,
            downlink_wire_bytes: rng.next_u64() >> 40,
            shard_uplink_wire_bytes: rng.next_u64() >> 44,
            shard_downlink_wire_bytes: rng.next_u64() >> 44,
            stragglers: rng.index(16),
        });
    }
    if rng.bernoulli(0.5) {
        let mut rejects = [0u64; sparsignd::coordinator::REJECT_KINDS];
        for r in rejects.iter_mut() {
            *r = rng.next_u64() >> 48;
        }
        ledger.add_rejects(&rejects);
    }
    let mut params = vec![0.0f32; dim];
    rng.fill_normal(&mut params, 0.0, 1.0);
    let residual = rng.bernoulli(0.5).then(|| {
        let mut r = vec![0.0f32; dim];
        rng.fill_normal(&mut r, 0.0, 0.1);
        r
    });
    CoordinatorSnapshot {
        fingerprint: rng.next_u64(),
        dim,
        workers: 1 + rng.index(1000),
        rounds_total,
        phase: if next == 0 { SnapPhase::Standby } else { SnapPhase::Broadcast(next - 1) },
        selection: if rng.bernoulli(0.5) {
            SelectionSnapshot::LegacyRaw(Pcg64::seed_from(rng.next_u64()).to_raw())
        } else {
            SelectionSnapshot::Committed {
                commitment: selection_commitment(&selection_root_key(rng.next_u64())),
                round: next as u64,
            }
        },
        params,
        residual,
        reports,
        ledger,
    }
}

#[test]
fn prop_snapshot_roundtrip_bit_identical() {
    check(cfg(64, 0x181), gen_snapshot, |snap| {
        let bytes = snap.encode();
        let back = CoordinatorSnapshot::decode(&bytes).map_err(|e| format!("decode: {e}"))?;
        if &back != snap {
            return Err("snapshot round-trip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_snapshot_single_byte_mutations_yield_typed_errors() {
    check(
        cfg(96, 0x182),
        |rng| {
            let bytes = gen_snapshot(rng).encode();
            let at = rng.index(bytes.len());
            let flip = 1 + rng.index(255) as u8;
            (bytes, at, flip)
        },
        |case| {
            let (bytes, at, flip) = case;
            let mut bad = bytes.clone();
            bad[*at] ^= *flip;
            // Header checks catch the first six bytes, CRC-32 catches
            // every ≤32-bit burst in the length/body, and a flip inside
            // the trailing CRC itself reads as BadCrc — every single-byte
            // corruption must surface as a typed error.
            match CoordinatorSnapshot::decode(&bad) {
                Err(_) => Ok(()),
                Ok(_) => Err(format!("mutation at {at} (^{flip:#x}) decoded")),
            }
        },
    );
}

#[test]
fn prop_snapshot_truncations_yield_typed_errors() {
    check(
        cfg(48, 0x183),
        |rng| {
            let bytes = gen_snapshot(rng).encode();
            let cut = rng.index(bytes.len());
            (bytes, cut)
        },
        |case| {
            let (bytes, cut) = case;
            match CoordinatorSnapshot::decode(&bytes[..*cut]) {
                Err(SnapshotError::Truncated { .. }) => Ok(()),
                Err(other) => Err(format!("cut {cut}: wrong error {other}")),
                Ok(_) => Err(format!("cut {cut}: decoded a prefix")),
            }
        },
    );
}

#[test]
fn snapshot_version_bump_is_refused() {
    let mut rng = Pcg64::seed_from(0x184);
    let mut bytes = gen_snapshot(&mut rng).encode();
    bytes[4] = SNAP_VERSION + 1;
    assert!(matches!(
        CoordinatorSnapshot::decode(&bytes),
        Err(SnapshotError::BadVersion { got }) if got == SNAP_VERSION + 1
    ));
}

/// Golden layout pin for snapshot version 3: an independent re-encoding
/// of the DESIGN.md §12/§13/§14 grammar must byte-match the codec's
/// output for a fixed state. Any layout change breaks this test, forcing
/// a version bump (and a new golden) rather than a silent format drift.
#[test]
fn snapshot_v3_golden_layout() {
    // Independent LEB128 (deliberately re-implemented, not imported).
    fn varint(out: &mut Vec<u8>, mut v: u64) {
        loop {
            let b = (v & 0x7f) as u8;
            v >>= 7;
            if v == 0 {
                out.push(b);
                break;
            }
            out.push(b | 0x80);
        }
    }
    let rng_raw = [0x1111u64, 0x2222, 0x3333 | 1, 0x4444];
    let rejects = [1u64, 0, 2, 0, 0, 300];
    let snap = CoordinatorSnapshot {
        fingerprint: 0x0102_0304_0506_0708,
        dim: 3,
        workers: 2,
        rounds_total: 4,
        phase: SnapPhase::Broadcast(0),
        selection: SelectionSnapshot::LegacyRaw(rng_raw),
        params: vec![1.0, -2.5, 0.0],
        residual: None,
        reports: vec![RoundReport {
            round: 0,
            lr: 0.5,
            train_loss: 2.0,
            eval: Some((1.25, 0.75)),
            uplink_bits: 300.0,
            downlink_bits: 64.0,
            cum_uplink_bits: 300.0,
        }],
        ledger: CommLedger::from_records_with_rejects(
            vec![RoundComm {
                uplink_bits: 300.0,
                downlink_bits: 64.0,
                senders: 2,
                uplink_nnz: 5,
                uplink_wire_bytes: 130,
                downlink_wire_bytes: 260,
                shard_uplink_wire_bytes: 48,
                shard_downlink_wire_bytes: 24,
                stragglers: 0,
            }],
            rejects,
        ),
    };

    // body := fingerprint dim workers rounds_total next_round phase
    //         selection params residual_flag reports ledger rejects
    let mut body = Vec::new();
    body.extend_from_slice(&0x0102_0304_0506_0708u64.to_le_bytes());
    varint(&mut body, 3); // dim
    varint(&mut body, 2); // workers
    varint(&mut body, 4); // rounds_total
    varint(&mut body, 1); // next_round
    body.push(1); // phase tag: Broadcast
    varint(&mut body, 0); // phase round
    body.push(0); // selection tag: legacy raw
    for w in rng_raw {
        body.extend_from_slice(&w.to_le_bytes());
    }
    for p in [1.0f32, -2.5, 0.0] {
        body.extend_from_slice(&p.to_le_bytes());
    }
    body.push(0); // no residual
    varint(&mut body, 1); // one report
    varint(&mut body, 0); // round
    body.extend_from_slice(&0.5f64.to_le_bytes()); // lr
    body.extend_from_slice(&2.0f64.to_le_bytes()); // train_loss
    body.push(1); // eval present
    body.extend_from_slice(&1.25f64.to_le_bytes());
    body.extend_from_slice(&0.75f64.to_le_bytes());
    body.extend_from_slice(&300.0f64.to_le_bytes()); // uplink_bits
    body.extend_from_slice(&64.0f64.to_le_bytes()); // downlink_bits
    body.extend_from_slice(&300.0f64.to_le_bytes()); // cum_uplink_bits
    varint(&mut body, 1); // one ledger record
    body.extend_from_slice(&300.0f64.to_le_bytes());
    body.extend_from_slice(&64.0f64.to_le_bytes());
    varint(&mut body, 2); // senders
    varint(&mut body, 5); // nnz
    varint(&mut body, 130); // uplink wire bytes
    varint(&mut body, 260); // downlink wire bytes
    varint(&mut body, 0); // stragglers
    varint(&mut body, 48); // shard-tier uplink wire bytes (v3)
    varint(&mut body, 24); // shard-tier downlink wire bytes (v3)
    for r in rejects {
        varint(&mut body, r); // cumulative typed rejects by kind
    }

    // file := magic("SGSP") version kind len body crc
    let mut expect = Vec::new();
    expect.extend_from_slice(&SNAP_MAGIC.to_be_bytes());
    assert_eq!(&expect[..4], b"SGSP");
    expect.push(SNAP_VERSION);
    expect.push(KIND_COORDINATOR);
    varint(&mut expect, body.len() as u64);
    expect.extend_from_slice(&body);
    let crc = wire::crc32(&expect);
    expect.extend_from_slice(&crc.to_le_bytes());

    assert_eq!(snap.encode(), expect, "snapshot v3 layout drifted — bump SNAP_VERSION");
    assert_eq!(CoordinatorSnapshot::decode(&expect).expect("golden decodes"), snap);
}

/// Hostile interior lengths: a frame whose payload declares a gigantic
/// gradient dimension must be refused by bounds checks before any
/// allocation happens (the decode path only ever allocates what the
/// payload bytes can back).
#[test]
fn wire_hostile_dims_never_allocate() {
    // Ternary kind with dim = 2^60 and a 16-byte payload.
    let mut payload = Vec::new();
    wire::push_varint(&mut payload, 3); // t
    wire::push_varint(&mut payload, 1); // worker
    payload.extend_from_slice(&0.5f64.to_le_bytes()); // loss
    payload.push(0); // ternary kind
    wire::push_varint(&mut payload, 1u64 << 60); // dim
    wire::push_varint(&mut payload, 4); // nnz
    let err = wire::decode_update(&payload).unwrap_err();
    assert!(matches!(err, WireError::Malformed(_)), "{err}");

    // Dense kind with dim far beyond the remaining bytes.
    let mut payload = Vec::new();
    wire::push_varint(&mut payload, 3);
    wire::push_varint(&mut payload, 1);
    payload.extend_from_slice(&0.5f64.to_le_bytes());
    payload.push(1); // dense kind
    wire::push_varint(&mut payload, u64::MAX); // dim
    payload.extend_from_slice(&1.0f64.to_le_bytes());
    let err = wire::decode_update(&payload).unwrap_err();
    assert!(matches!(err, WireError::Malformed(_)), "{err}");

    // A stream-framed hostile length is capped before buffering.
    let mut hostile = Vec::new();
    hostile.extend_from_slice(&wire::MAGIC.to_be_bytes());
    hostile.push(wire::WIRE_VERSION);
    hostile.push(4); // Update
    wire::push_varint(&mut hostile, u64::MAX / 4);
    let mut cursor = std::io::Cursor::new(hostile);
    let mut buf = Vec::new();
    let read = sparsignd::net::read_frame_bytes(&mut cursor, wire::MAX_PAYLOAD, &mut buf);
    let err = read.unwrap_err();
    assert!(matches!(err, NetError::Wire(WireError::Oversized { .. })), "{err}");
}

// ---------------------------------------------------------------------
// Store-loader hardening (DESIGN.md §16): `.sgds` images face the same
// hostility battery as the wire and snapshot codecs — random corruption,
// truncation, and forged-but-checksummed headers all land on typed
// `StoreError`s, with caps enforced before the allocations they bound.
// ---------------------------------------------------------------------

use sparsignd::data::{
    encode_store, Dataset, DirichletPartitioner, FederatedDataset, ShardStore, StoreError,
    SyntheticSpec, SyntheticTask, STORE_VERSION,
};

/// Small but fully populated store image (multi-client manifest, distinct
/// train/test splits) for the corruption battery.
fn small_store_image(seed: u64) -> Vec<u8> {
    let task = SyntheticTask::generate(
        SyntheticSpec { train: 60, test: 12, ..SyntheticSpec::fmnist_like().with_dim(6) },
        seed,
    );
    let fed = DirichletPartitioner { alpha: 0.5, workers: 5 }
        .partition_exact(&task.train, &mut Pcg64::seed_from(seed ^ 0x51));
    encode_store(&task.train, &task.test, &fed, 0.5, seed).unwrap()
}

/// Independent re-encoding of the SGDS v1 grammar (DESIGN.md §16):
/// header, varint meta with an explicitly forgeable client count, the
/// 64-byte-aligned feature block, labels, and a whole-file CRC.
/// Deliberately not built on `encode_store`, so the hostile cases below
/// can violate every cross-field invariant while still carrying a valid
/// checksum — proving the semantic validators, not just the CRC, reject
/// them.
#[derive(Clone, Copy)]
struct Forge<'a> {
    dim: u64,
    rows_train: u64,
    rows_test: u64,
    classes: u64,
    declared_clients: u64,
    shard_lens: &'a [u64],
    alpha: f64,
    feat: &'a [f32],
    labels: &'a [u32],
}

impl Forge<'_> {
    fn build(&self) -> Vec<u8> {
        let mut meta = Vec::new();
        for v in [self.dim, self.rows_train, self.rows_test, self.classes, self.declared_clients] {
            wire::push_varint(&mut meta, v);
        }
        meta.extend_from_slice(&self.alpha.to_le_bytes());
        meta.extend_from_slice(&9u64.to_le_bytes()); // manifest seed
        for &l in self.shard_lens {
            wire::push_varint(&mut meta, l);
        }
        let mut out = Vec::new();
        out.extend_from_slice(b"SGDS");
        out.push(STORE_VERSION);
        out.push(1); // kind: dense f32 dataset
        wire::push_varint(&mut out, meta.len() as u64);
        out.extend_from_slice(&meta);
        let feat_off = out.len().next_multiple_of(64);
        out.resize(feat_off, 0);
        for &v in self.feat {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for &y in self.labels {
            out.extend_from_slice(&y.to_le_bytes());
        }
        seal(&mut out);
        out
    }
}

/// Append a fresh whole-file CRC — so tampered images decode far enough
/// to reach the semantic validators instead of dying at the checksum.
fn seal(out: &mut Vec<u8>) {
    let crc = wire::crc32(out);
    out.extend_from_slice(&crc.to_le_bytes());
}

/// A consistent four-row, two-client image every hostile case perturbs.
fn forge_base() -> Forge<'static> {
    Forge {
        dim: 2,
        rows_train: 4,
        rows_test: 2,
        classes: 2,
        declared_clients: 2,
        shard_lens: &[2, 2],
        alpha: 0.5,
        feat: &[1.0, -2.0, 0.5, 3.0, 0.0, -1.5, 2.25, 4.0, 0.25, -0.75, 1.5, 0.125],
        labels: &[0, 1, 1, 0, 1, 0],
    }
}

/// Golden layout pin for store version 1: an independent re-encoding of
/// the grammar must byte-match `encode_store` for a fixed dataset. Any
/// layout change breaks this test, forcing a STORE_VERSION bump (and a
/// new golden) rather than a silent format drift.
#[test]
fn store_v1_golden_layout() {
    let train = Dataset {
        x: vec![1.0, -2.0, 0.5, 3.0, 0.0, -1.5, 2.25, 4.0].into(),
        y: vec![0, 1, 1, 0],
        dim: 2,
        classes: 2,
    };
    let test = Dataset {
        x: vec![0.25, -0.75, 1.5, 0.125].into(),
        y: vec![1, 0],
        dim: 2,
        classes: 2,
    };
    let fed = FederatedDataset::from_ranges(vec![(0, 2), (2, 2)]);
    let got = encode_store(&train, &test, &fed, 0.5, 9).unwrap();
    // Ranges (0,2),(2,2) regroup the train rows in identity order; the
    // test rows and all labels follow in the same order — exactly the
    // flat feat/labels in `forge_base`.
    let want = forge_base().build();
    assert_eq!(got, want, "store v1 layout drifted — bump STORE_VERSION");
    let store = ShardStore::from_bytes(want).expect("golden image decodes");
    assert_eq!((store.dim(), store.classes(), store.clients()), (2, 2, 2));
}

#[test]
fn prop_store_single_byte_mutations_yield_typed_errors() {
    let image = small_store_image(0x190);
    check(
        cfg(96, 0x191),
        |rng| (rng.index(image.len()), 1 + rng.index(255) as u8),
        |&(at, flip)| {
            let mut bad = image.clone();
            bad[at] ^= flip;
            // The first six bytes land on BadMagic/BadVersion/BadKind;
            // everywhere else the whole-file CRC — checked before any
            // field parsing — reads as BadCrc. Nothing ever decodes.
            match ShardStore::from_bytes(bad) {
                Err(_) => Ok(()),
                Ok(_) => Err(format!("mutation at {at} (^{flip:#x}) decoded")),
            }
        },
    );
}

#[test]
fn prop_store_truncations_yield_typed_errors() {
    let image = small_store_image(0x192);
    check(
        cfg(64, 0x193),
        |rng| rng.index(image.len()),
        |&cut| match ShardStore::from_bytes(image[..cut].to_vec()) {
            Err(StoreError::Truncated { .. } | StoreError::BadCrc { .. }) => Ok(()),
            Err(other) => Err(format!("cut {cut}: wrong error {other}")),
            Ok(_) => Err(format!("cut {cut}: decoded a prefix")),
        },
    );
}

/// Forged headers with valid checksums: every cross-field invariant the
/// decoder enforces must reject its violation as a typed `Malformed`,
/// with caps checked before the manifest/feature work they bound.
#[test]
fn store_hostile_headers_yield_typed_errors() {
    let base = forge_base();
    match ShardStore::from_bytes(base.build()) {
        Ok(_) => {}
        Err(e) => panic!("baseline forge must load: {e}"),
    }
    let cases = [
        ("dim over cap", Forge { dim: 1 << 40, ..base }.build()),
        ("rows over cap", Forge { rows_train: 1 << 40, ..base }.build()),
        ("zero dim", Forge { dim: 0, ..base }.build()),
        ("one class", Forge { classes: 1, ..base }.build()),
        ("clients exceed manifest bytes", Forge { declared_clients: 100_000, ..base }.build()),
        ("empty client shard", Forge { shard_lens: &[0, 4], ..base }.build()),
        ("manifest overruns train rows", Forge { shard_lens: &[3, 3], ..base }.build()),
        ("manifest undercovers train rows", Forge { shard_lens: &[2, 1], ..base }.build()),
        ("zero alpha", Forge { alpha: 0.0, ..base }.build()),
        ("NaN alpha", Forge { alpha: f64::NAN, ..base }.build()),
        ("label out of class range", Forge { labels: &[0, 1, 1, 0, 1, 9], ..base }.build()),
    ];
    for (what, bytes) in cases {
        match ShardStore::from_bytes(bytes) {
            Err(StoreError::Malformed(_)) => {}
            other => panic!("{what}: expected Malformed, got {:?}", other.err()),
        }
    }
}

/// Well-formed headers whose declared layout disagrees with the bytes
/// actually present — or that smuggle data into the alignment gap — are
/// refused even under a correct checksum, and a layout whose declared
/// feature block dwarfs the file costs only an O(manifest-bytes)
/// allocation to refuse.
#[test]
fn store_layout_cross_checks_catch_padding_trailing_and_huge_declarations() {
    let good = forge_base().build();

    // Nonzero alignment padding (a covert channel): the meta block of
    // this image ends at byte 30, so bytes 30..64 are the alignment gap.
    let mut padded = good.clone();
    padded.truncate(good.len() - 4);
    assert_eq!(padded[40], 0, "expected alignment padding at byte 40");
    padded[40] = 1;
    seal(&mut padded);
    match ShardStore::from_bytes(padded) {
        Err(StoreError::Malformed(_)) => {}
        other => panic!("padding: expected Malformed, got {:?}", other.err()),
    }

    // Bytes smuggled after the label block flunk the total-length check.
    let mut trailing = good.clone();
    trailing.truncate(good.len() - 4);
    trailing.extend_from_slice(&[0u8; 4]);
    seal(&mut trailing);
    match ShardStore::from_bytes(trailing) {
        Err(StoreError::Malformed(_)) => {}
        other => panic!("trailing: expected Malformed, got {:?}", other.err()),
    }

    // Caps admit dim = 2^26 and rows = 2^28, but the implied ~2^56-byte
    // feature block dwarfs the file: the length cross-check refuses it as
    // Truncated without ever touching (or allocating) the declared size.
    let huge = Forge {
        dim: 1 << 26,
        rows_train: 1 << 28,
        rows_test: 1,
        declared_clients: 1,
        shard_lens: &[1 << 28],
        feat: &[],
        labels: &[],
        ..forge_base()
    }
    .build();
    match ShardStore::from_bytes(huge) {
        Err(StoreError::Truncated { .. }) => {}
        other => panic!("huge layout: expected Truncated, got {:?}", other.err()),
    }
}
