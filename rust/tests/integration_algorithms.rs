//! Algorithm-level integration: the paper's headline behaviours on
//! adversarial and heterogeneous workloads, exercised through the full
//! coordinator stack.

use sparsignd::compressors::CompressorKind;
use sparsignd::config::ExperimentConfig;
use sparsignd::coordinator::{
    AggregationRule, Algorithm, Attack, AttackPlan, RosenbrockEnv, TrainingRun,
};
use sparsignd::experiments::build_env;
use sparsignd::model::rosenbrock::{Rosenbrock, ScaledObjectiveWorkers};
use sparsignd::optim::LrSchedule;
use sparsignd::util::rng::Pcg64;

fn rosen_env(seed: u64) -> RosenbrockEnv {
    let mut rng = Pcg64::seed_from(seed);
    RosenbrockEnv {
        f: Rosenbrock::new(10),
        scales: ScaledObjectiveWorkers::generate_scaled(100, 80, 0.01, &mut rng),
        noise_std: 0.0,
    }
}

fn run_rosen(alg: Algorithm, rounds: usize, participation: f64, seed: u64) -> f64 {
    let env = rosen_env(seed);
    let run = TrainingRun {
        algorithm: alg,
        schedule: LrSchedule::Const { lr: 0.01 },
        rounds,
        participation,
        eval_every: 0,
        seed,
        attack: None,
        selection: Default::default(),
        allow_stateful_with_sampling: false,
        threads: None,
    };
    let hist = run.run(&env, vec![0.0; 10], &|p| (env.f.value(p), 0.0));
    env.f.value(&hist.final_params)
}

/// The paper's core claim end-to-end: under eq. (11) heterogeneity,
/// signSGD majority vote diverges while SPARSIGNSGD converges.
#[test]
fn signsgd_diverges_sparsign_converges() {
    let sign = run_rosen(
        Algorithm::CompressedGd {
            compressor: CompressorKind::Sign,
            aggregation: AggregationRule::MajorityVote,
        },
        1_500,
        1.0,
        9,
    );
    let sparsign = run_rosen(
        Algorithm::CompressedGd {
            compressor: CompressorKind::Sparsign { budget: 0.1 },
            aggregation: AggregationRule::MajorityVote,
        },
        1_500,
        1.0,
        9,
    );
    let f0 = 9.0;
    assert!(sign > 100.0 * f0, "signSGD should diverge hard, got F = {sign}");
    assert!(sparsign < f0, "sparsign should descend, got F = {sparsign}");
}

/// Worker-EF signSGD actually *works* under full participation (it is a
/// valid fix) — and the engine is what forbids the stale-state
/// configuration; with the override, sampled EF keeps stale residuals.
#[test]
fn worker_ef_fixes_sign_under_full_participation() {
    let ef_sign = run_rosen(
        Algorithm::CompressedGd {
            compressor: CompressorKind::WorkerEf(Box::new(CompressorKind::ScaledSign)),
            aggregation: AggregationRule::Mean,
        },
        1_500,
        1.0,
        10,
    );
    assert!(
        ef_sign < 9.0,
        "EF-scaled-sign with full participation should converge, got {ef_sign}"
    );
}

/// Re-scaling attack (Remark 2): sparsign's accuracy degrades gracefully
/// while the magnitude-scaled compressor collapses.
#[test]
fn rescale_attack_hurts_norm_scaled_compressors_more() {
    let mut cfg = ExperimentConfig::fast_preset();
    cfg.rounds = 100;
    let attack = Some(AttackPlan::new(Attack::Rescale { factor: 1e4 }, 4));

    let final_acc = |kind: CompressorKind, agg: AggregationRule, lr: f64, attack: Option<AttackPlan>| {
        let env = build_env(&cfg, 0xda7a);
        let mut init_rng = Pcg64::new(0, 0x1217);
        let init = env.init_params(&mut init_rng);
        let run = TrainingRun {
            algorithm: Algorithm::CompressedGd { compressor: kind, aggregation: agg },
            schedule: LrSchedule::Const { lr },
            rounds: cfg.rounds,
            participation: 1.0,
            eval_every: 0,
            seed: 0,
            attack,
            selection: Default::default(),
            allow_stateful_with_sampling: false,
            threads: None,
        };
        let hist = run.run(&env, init, &|p| env.evaluate(p));
        hist.final_eval().unwrap().1
    };

    let sparsign_clean =
        final_acc(CompressorKind::Sparsign { budget: 1.0 }, AggregationRule::MajorityVote, 0.005, None);
    let sparsign_attacked = final_acc(
        CompressorKind::Sparsign { budget: 1.0 },
        AggregationRule::MajorityVote,
        0.005,
        attack.clone(),
    );
    let terngrad_clean =
        final_acc(CompressorKind::TernGrad, AggregationRule::Mean, 0.05, None);
    let terngrad_attacked =
        final_acc(CompressorKind::TernGrad, AggregationRule::Mean, 0.05, attack);

    let sparsign_drop = sparsign_clean - sparsign_attacked;
    let terngrad_drop = terngrad_clean - terngrad_attacked;
    println!(
        "sparsign {sparsign_clean:.3}→{sparsign_attacked:.3} (drop {sparsign_drop:.3}); \
         terngrad {terngrad_clean:.3}→{terngrad_attacked:.3} (drop {terngrad_drop:.3})"
    );
    assert!(
        terngrad_drop > sparsign_drop + 0.1,
        "norm-scaled compressor should suffer much more from re-scaling"
    );
    assert!(sparsign_drop < 0.15, "sparsign should be nearly unaffected");
}

/// Partial participation + heterogeneity: EF-SPARSIGNSGD (server-side EF
/// only) trains fine with 25% sampling — the configuration worker-EF
/// methods cannot support.
#[test]
fn ef_sparsign_trains_under_low_participation() {
    let mut cfg = ExperimentConfig::fast_preset();
    cfg.rounds = 150;
    cfg.alpha = 0.1;
    let env = build_env(&cfg, 0xda7a);
    let mut init_rng = Pcg64::new(0, 0x1217);
    let init = env.init_params(&mut init_rng);
    let run = TrainingRun {
        algorithm: Algorithm::EfSparsign {
            b_local: 10.0,
            b_global: 1.0,
            tau: 2,
            server_lr_scale: None,
            server_ef: true,
        },
        schedule: LrSchedule::Const { lr: 0.02 },
        rounds: cfg.rounds,
        participation: 0.25,
        eval_every: 0,
        seed: 1,
        attack: None,
        selection: Default::default(),
        allow_stateful_with_sampling: false,
        threads: None,
    };
    let hist = run.run(&env, init, &|p| env.evaluate(p));
    let (_, acc) = hist.final_eval().unwrap();
    assert!(acc > 0.5, "EF-sparsign @25% participation acc {acc}");
}

/// Local steps improve round efficiency (Theorem 3 / Table 3 direction):
/// τ=8 reaches a fixed loss level in fewer rounds than τ=1 for FedCom.
#[test]
fn local_steps_reduce_rounds_to_target() {
    let mut cfg = ExperimentConfig::fast_preset();
    cfg.rounds = 80;
    let env = build_env(&cfg, 0xda7a);
    let mut init_rng = Pcg64::new(0, 0x1217);
    let init = env.init_params(&mut init_rng);
    let rounds_to = |tau: usize| {
        let run = TrainingRun {
            algorithm: Algorithm::FedCom { tau, levels: 255 },
            schedule: LrSchedule::Const { lr: 0.05 },
            rounds: cfg.rounds,
            participation: 1.0,
            eval_every: 2,
            seed: 2,
            attack: None,
            selection: Default::default(),
            allow_stateful_with_sampling: false,
            threads: None,
        };
        let hist = run.run(&env, init.clone(), &|p| env.evaluate(p));
        hist.rounds_to_acc(0.6)
    };
    let r1 = rounds_to(1);
    let r8 = rounds_to(8);
    println!("rounds to 60%: τ=1 {r1:?} vs τ=8 {r8:?}");
    match (r1, r8) {
        (Some(a), Some(b)) => assert!(b < a, "τ=8 ({b}) should beat τ=1 ({a})"),
        (None, Some(_)) => {} // τ=8 reached it, τ=1 didn't — even stronger
        other => panic!("unexpected: {other:?}"),
    }
}

/// Golomb-accounted ternary uplink beats dense 1-bit as soon as the
/// message is sparse — verified through the full engine's ledger.
#[test]
fn sparsign_uplink_beats_dense_sign_when_sparse() {
    let mut cfg = ExperimentConfig::fast_preset();
    cfg.rounds = 20;
    let env = build_env(&cfg, 0xda7a);
    let mut init_rng = Pcg64::new(0, 0x1217);
    let init = env.init_params(&mut init_rng);
    let uplink = |kind: CompressorKind| {
        let run = TrainingRun {
            algorithm: Algorithm::CompressedGd {
                compressor: kind,
                aggregation: AggregationRule::MajorityVote,
            },
            schedule: LrSchedule::Const { lr: 0.01 },
            rounds: cfg.rounds,
            participation: 1.0,
            eval_every: 0,
            seed: 3,
            attack: None,
            selection: Default::default(),
            allow_stateful_with_sampling: false,
            threads: None,
        };
        run.run(&env, init.clone(), &|p| env.evaluate(p)).total_uplink()
    };
    let dense = uplink(CompressorKind::Sign);
    let sparse = uplink(CompressorKind::Sparsign { budget: 0.1 });
    assert!(
        sparse < dense / 2.0,
        "sparsign(B=0.1) uplink {sparse:.0} should be ≪ sign {dense:.0}"
    );
}
