//! The motivating sweep from the paper's introduction: how does each
//! compressor family degrade as data heterogeneity grows?
//!
//! Runs signSGD, TernGrad, SPARSIGNSGD and EF-SPARSIGNSGD across
//! Dirichlet α ∈ {0.05, 0.1, 0.5, 1, 10} and prints final accuracy per
//! cell — sign-based majority vote should collapse at low α while the
//! magnitude-aware compressor holds.
//!
//! ```bash
//! cargo run --release --example heterogeneity_sweep
//! ```

use sparsignd::compressors::CompressorKind;
use sparsignd::config::ExperimentConfig;
use sparsignd::coordinator::{AggregationRule, Algorithm};
use sparsignd::experiments::run_classification;
use sparsignd::metrics::TablePrinter;

fn main() {
    let alphas = [0.05, 0.1, 0.5, 1.0, 10.0];
    let algorithms = vec![
        Algorithm::CompressedGd {
            compressor: CompressorKind::Sign,
            aggregation: AggregationRule::MajorityVote,
        },
        Algorithm::CompressedGd {
            compressor: CompressorKind::TernGrad,
            aggregation: AggregationRule::Mean,
        },
        Algorithm::CompressedGd {
            compressor: CompressorKind::Sparsign { budget: 1.0 },
            aggregation: AggregationRule::MajorityVote,
        },
        Algorithm::EfSparsign { b_local: 10.0, b_global: 1.0, tau: 1, server_lr_scale: None, server_ef: true },
    ];
    let lr_overrides = vec![Some(0.005), Some(0.05), Some(0.005), Some(0.005)];

    let mut table = TablePrinter::new(
        "Final accuracy vs heterogeneity (lower α = more skew)",
        &["Algorithm", "α=0.05", "α=0.1", "α=0.5", "α=1", "α=10"],
    );
    let mut cells: Vec<Vec<String>> = algorithms
        .iter()
        .map(|a| vec![a.label()])
        .collect();

    for &alpha in &alphas {
        let mut cfg = ExperimentConfig::fast_preset();
        cfg.name = format!("sweep α={alpha}");
        cfg.alpha = alpha;
        cfg.rounds = 120;
        cfg.seeds = vec![0, 1];
        cfg.algorithms = algorithms.clone();
        cfg.lr_overrides = lr_overrides.clone();
        let report = run_classification(&cfg);
        println!(
            "α = {alpha}: partition skew (mean max class fraction) = {:.3}",
            report.mean_max_class_fraction
        );
        for (row, s) in cells.iter_mut().zip(&report.summaries) {
            row.push(format!("{:.1}%", 100.0 * s.final_acc_mean));
        }
    }
    for row in cells {
        table.add_row(row);
    }
    println!("\n{}", table.render());
    println!(
        "Expected shape: majority-vote sign shows the STEEPEST relative \
         degradation as α shrinks (heterogeneous signs cancel), while the \
         magnitude-aware rows degrade gently. Note signSGD does not fully \
         collapse under label-skew + mini-batch noise (the paper's own \
         Table 1 shows it reaching 74%); the catastrophic regime is the \
         adversarial eq. (11) population of Fig. 1 (`examples/rosenbrock`)."
    );
}
