//! End-to-end driver across all three layers:
//!
//!   L1 Pallas sparsign (fused into the HLO gradient graphs)
//!   L2 JAX MLP fwd/bwd, AOT-lowered to `artifacts/mlp_fmnist_*.hlo.txt`
//!   L3 rust coordinator running EF-SPARSIGNSGD over the PJRT executables
//!
//! Trains the paper's §C.2 784-256-128-10 MLP (235,146 parameters) on the
//! fmnist-like synthetic task under Dirichlet(0.1) skew and logs the loss
//! curve (`fmnist_e2e_curve.csv`). Run `make artifacts` first.
//!
//! ```bash
//! cargo run --release --example fmnist_e2e -- [rounds] [workers]
//! ```

use sparsignd::coordinator::{Algorithm, ClassifierEnv, TrainingRun};
use sparsignd::data::{DirichletPartitioner, SyntheticSpec, SyntheticTask};
use sparsignd::metrics::write_csv;
use sparsignd::optim::LrSchedule;
use sparsignd::runtime::{HloModel, Runtime};
use sparsignd::util::rng::Pcg64;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut args = std::env::args().skip(1);
    let rounds: usize = args.next().and_then(|a| a.parse().ok()).unwrap_or(60);
    let workers: usize = args.next().and_then(|a| a.parse().ok()).unwrap_or(12);

    println!("loading PJRT runtime + AOT artifacts …");
    let runtime = std::rc::Rc::new(Runtime::cpu("artifacts")?);
    println!("  platform: {}", runtime.platform());
    let model = HloModel::load(runtime, "mlp_fmnist", 784, vec![256, 128], 10)?;
    let batch = model.batch();
    println!("  model: {} ({} params)", sparsignd::model::Model::describe(&model), sparsignd::model::Model::dim(&model));

    // fmnist-like task (10k examples), Dirichlet(0.3) label skew.
    let spec = SyntheticSpec::fmnist_like();
    let task = SyntheticTask::generate(spec, 42);
    let mut prng = Pcg64::seed_from(43);
    let fed = DirichletPartitioner { alpha: 0.3, workers }.partition(&task.train, &mut prng);
    let env = ClassifierEnv::new(Box::new(model), task.train, task.test, fed, batch);

    let run = TrainingRun {
        algorithm: Algorithm::EfSparsign {
            b_local: 10.0,
            b_global: 1.0,
            tau: 1,
            server_lr_scale: None,
            server_ef: true,
        },
        schedule: LrSchedule::Const { lr: 0.01 },
        rounds,
        participation: 0.5,
        eval_every: 5,
        seed: 7,
        attack: None,
        allow_stateful_with_sampling: false,
        // HloModel's PJRT cache is Rc/RefCell-based (single-threaded by
        // contract), so pin the round engine to the serial reference.
        threads: Some(1),
    };

    println!(
        "training EF-SPARSIGNSGD (B_l=10, B_g=1, τ=1): {} workers, 50% participation, {} rounds\n",
        workers, rounds
    );
    let mut init_rng = Pcg64::seed_from(1);
    let init = env.init_params(&mut init_rng);
    let t0 = std::time::Instant::now();
    let hist = run.run(&env, init, &|p| env.evaluate(p));
    let wall = t0.elapsed().as_secs_f64();

    let mut rows = Vec::new();
    for r in &hist.reports {
        if let Some((loss, acc)) = r.eval {
            println!(
                "  round {:>4}  train_loss {:>7.4}  test_loss {:>7.4}  test_acc {:>6.3}  cum_uplink {:>12.0} bits",
                r.round + 1,
                r.train_loss,
                loss,
                acc,
                r.cum_uplink_bits
            );
        }
        rows.push(vec![
            (r.round + 1).to_string(),
            format!("{:.6}", r.train_loss),
            r.eval.map(|(l, _)| format!("{l:.6}")).unwrap_or_default(),
            r.eval.map(|(_, a)| format!("{a:.6}")).unwrap_or_default(),
            format!("{:.0}", r.cum_uplink_bits),
        ]);
    }
    write_csv(
        "fmnist_e2e_curve.csv",
        &["round", "train_loss", "test_loss", "test_acc", "cum_uplink_bits"],
        &rows,
    )?;

    let (final_loss, final_acc) = hist.final_eval().unwrap();
    let first_loss = hist.reports.first().unwrap().train_loss;
    println!(
        "\ndone in {wall:.1}s: train loss {first_loss:.3} → {:.3}, test acc {final_acc:.3}, \
         total uplink {:.2e} bits ({:.1}× less than fp32 D-SGD)",
        final_loss,
        hist.total_uplink(),
        (rounds as f64 * (workers as f64 * 0.5) * 32.0 * hist.dim as f64) / hist.total_uplink()
    );
    println!("loss curve → fmnist_e2e_curve.csv");
    if final_loss >= first_loss {
        return Err("loss did not decrease".into());
    }
    Ok(())
}
