//! Remark 2(4): sparsign is robust to re-scaling attacks because no
//! magnitude is ever exchanged — a malicious worker can multiply its
//! gradient by 10⁶ and still flips at most ±1 per coordinate, while
//! norm-scaled compressors (TernGrad, QSGD) let it dominate the average.
//!
//! ```bash
//! cargo run --release --example attack_robustness
//! ```

use sparsignd::compressors::{CompressorKind, NormKind};
use sparsignd::config::ExperimentConfig;
use sparsignd::coordinator::{AggregationRule, Algorithm, Attack, AttackPlan, TrainingRun};
use sparsignd::experiments::build_env;
use sparsignd::metrics::TablePrinter;
use sparsignd::util::rng::Pcg64;

fn main() {
    let rosters: Vec<(Algorithm, f64)> = vec![
        (
            Algorithm::CompressedGd {
                compressor: CompressorKind::Sparsign { budget: 1.0 },
                aggregation: AggregationRule::MajorityVote,
            },
            0.005,
        ),
        (
            Algorithm::CompressedGd {
                compressor: CompressorKind::TernGrad,
                aggregation: AggregationRule::Mean,
            },
            0.05,
        ),
        (
            Algorithm::CompressedGd {
                compressor: CompressorKind::Qsgd { levels: 1, norm: NormKind::L2 },
                aggregation: AggregationRule::Mean,
            },
            0.05,
        ),
    ];
    let attacks: Vec<(&str, Option<AttackPlan>)> = vec![
        ("clean", None),
        (
            "rescale ×1e4 (20% malicious)",
            Some(AttackPlan { attack: Attack::Rescale { factor: 1e4 }, malicious: 4 }),
        ),
        (
            "sign-flip (20% malicious)",
            Some(AttackPlan { attack: Attack::SignFlip, malicious: 4 }),
        ),
    ];

    let mut cfg = ExperimentConfig::fast_preset();
    cfg.rounds = 120;
    let mut table = TablePrinter::new(
        "Final accuracy under attack (20 workers, fast task)",
        &["Algorithm", "clean", "rescale ×1e4", "sign-flip"],
    );

    for (alg, lr) in &rosters {
        let mut row = vec![alg.label()];
        for (_, plan) in &attacks {
            let env = build_env(&cfg, 0xda7a);
            let mut init_rng = Pcg64::new(0, 0x1217);
            let init = env.init_params(&mut init_rng);
            let run = TrainingRun {
                algorithm: alg.clone(),
                schedule: sparsignd::optim::LrSchedule::Const { lr: *lr },
                rounds: cfg.rounds,
                participation: 1.0,
                eval_every: 0,
                seed: 0,
                attack: *plan,
                allow_stateful_with_sampling: false,
                threads: None,
            };
            let hist = run.run(&env, init, &|p| env.evaluate(p));
            let (_, acc) = hist.final_eval().unwrap();
            row.push(format!("{:.1}%", 100.0 * acc));
        }
        table.add_row(row);
    }
    println!("{}", table.render());
    println!(
        "Expected shape: the re-scaling column hurts the norm-scaled \
         compressors (TernGrad / 1-bit QSGD decode to ‖g‖-scaled values) far \
         more than sparsign, whose messages are bounded in {{-1,0,1}}."
    );
}
