//! End-to-end federated **language-model** training through the PJRT
//! runtime: a decoder-only transformer (embedding-tied, pre-LN, 72,704
//! parameters at the sandbox scale — widen `TransformerSpec` in
//! `python/compile/model.py` for larger runs) trained with SPARSIGNSGD
//! majority vote on heterogeneous synthetic corpora.
//!
//! Each worker's corpus is a distinct modular-arithmetic token process
//! (next = token + stride_m mod V), so worker gradients genuinely
//! conflict — the LM analogue of label skew.
//!
//! ```bash
//! cargo run --release --example transformer_e2e -- [rounds]
//! ```

use sparsignd::compressors::CompressorKind;
use sparsignd::coordinator::{
    AggregationRule, Algorithm, GradientSource, TrainingRun,
};
use sparsignd::metrics::write_csv;
use sparsignd::optim::LrSchedule;
use sparsignd::runtime::{literal_i32, literal_u32, scalar_f32, vec_f32, Runtime};
use sparsignd::util::rng::Pcg64;

const VOCAB: usize = 64;
const SEQ: usize = 32;
const BATCH: usize = 8;
const DIM: usize = 72_704;

/// Federated LM environment backed by the `transformer_grad` artifact.
struct TransformerEnv {
    runtime: std::rc::Rc<Runtime>,
    workers: usize,
    /// Per-worker stride of the token process (the heterogeneity).
    strides: Vec<i32>,
}

// SAFETY: this example pins the round engine to `threads: Some(1)` (the
// Rc/RefCell PJRT cache is single-threaded by contract), and the
// executable cache is warmed before training starts.
unsafe impl Send for TransformerEnv {}
unsafe impl Sync for TransformerEnv {}

impl TransformerEnv {
    fn sample_tokens(&self, worker: usize, rng: &mut Pcg64) -> (Vec<i32>, Vec<i32>) {
        let stride = self.strides[worker];
        let mut tok = Vec::with_capacity(BATCH * SEQ);
        let mut tgt = Vec::with_capacity(BATCH * SEQ);
        for _ in 0..BATCH {
            let mut t = rng.index(VOCAB) as i32;
            for _ in 0..SEQ {
                tok.push(t);
                t = (t + stride).rem_euclid(VOCAB as i32);
                tgt.push(t);
            }
        }
        (tok, tgt)
    }

    fn loss_at(&self, params: &[f32], worker: usize, seed: u64) -> f64 {
        let mut rng = Pcg64::new(seed, worker as u64);
        let (tok, tgt) = self.sample_tokens(worker, &mut rng);
        let out = self
            .runtime
            .execute(
                "transformer_grad",
                &[
                    sparsignd::runtime::literal_f32(params, &[DIM as i64]).unwrap(),
                    literal_i32(&tok, &[BATCH as i64, SEQ as i64]).unwrap(),
                    literal_i32(&tgt, &[BATCH as i64, SEQ as i64]).unwrap(),
                ],
            )
            .expect("transformer_grad");
        scalar_f32(&out[0]).unwrap() as f64
    }
}

impl GradientSource for TransformerEnv {
    fn dim(&self) -> usize {
        DIM
    }

    fn workers(&self) -> usize {
        self.workers
    }

    fn serial_only(&self) -> bool {
        true // Rc/RefCell PJRT cache — the engine pins fan-out to 1 thread
    }

    fn sample_grad(&self, worker: usize, params: &[f32], rng: &mut Pcg64, out: &mut [f32]) -> f32 {
        let (tok, tgt) = self.sample_tokens(worker, rng);
        let res = self
            .runtime
            .execute(
                "transformer_grad",
                &[
                    sparsignd::runtime::literal_f32(params, &[DIM as i64]).unwrap(),
                    literal_i32(&tok, &[BATCH as i64, SEQ as i64]).unwrap(),
                    literal_i32(&tgt, &[BATCH as i64, SEQ as i64]).unwrap(),
                ],
            )
            .expect("transformer_grad");
        out.copy_from_slice(&vec_f32(&res[1]).unwrap());
        scalar_f32(&res[0]).unwrap()
    }
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let rounds: usize = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(120);
    println!("loading PJRT runtime + transformer artifacts …");
    let runtime = std::rc::Rc::new(Runtime::cpu("artifacts")?);
    println!("  platform: {}", runtime.platform());

    // Initialize via the AOT init artifact (LayerNorm gains = 1 etc. — the
    // init logic lives in L2, rust only supplies the key).
    let init_out = runtime.execute("transformer_init", &[literal_u32(&[1, 2], &[2])?])?;
    let init = vec_f32(&init_out[0])?;
    if init.len() != DIM {
        return Err(format!("init len {} != DIM {}", init.len(), DIM).into());
    }

    let workers = 8;
    let env = TransformerEnv {
        runtime,
        workers,
        // Heterogeneous strides: workers disagree about the "language".
        strides: (0..workers).map(|m| 1 + (m % 4) as i32).collect(),
    };

    let run = TrainingRun {
        algorithm: Algorithm::CompressedGd {
            compressor: CompressorKind::Sparsign { budget: 5.0 },
            aggregation: AggregationRule::MajorityVote,
        },
        schedule: LrSchedule::Const { lr: 0.004 },
        rounds,
        participation: 1.0,
        eval_every: 10,
        seed: 3,
        attack: None,
        allow_stateful_with_sampling: false,
        // See the TransformerEnv SAFETY note: PJRT cache is Rc/RefCell.
        threads: Some(1),
    };

    println!(
        "training SPARSIGNSGD(B=5) majority vote: {} workers, {} rounds, {} params\n",
        workers, rounds, DIM
    );
    let t0 = std::time::Instant::now();
    // Eval = mean held-out loss across three workers' distributions.
    let eval_env = &env;
    let hist = run.run(&env, init, &|p| {
        let loss = (0..3)
            .map(|w| eval_env.loss_at(p, w, 0xe7a1))
            .sum::<f64>()
            / 3.0;
        (loss, 0.0)
    });
    let wall = t0.elapsed().as_secs_f64();

    let mut rows = Vec::new();
    for r in &hist.reports {
        if let Some((loss, _)) = r.eval {
            println!(
                "  round {:>4}  train_loss {:>7.4}  eval_loss {:>7.4}  cum_uplink {:>12.0} bits",
                r.round + 1,
                r.train_loss,
                loss,
                r.cum_uplink_bits
            );
        }
        rows.push(vec![
            (r.round + 1).to_string(),
            format!("{:.6}", r.train_loss),
            r.eval.map(|(l, _)| format!("{l:.6}")).unwrap_or_default(),
            format!("{:.0}", r.cum_uplink_bits),
        ]);
    }
    write_csv(
        "transformer_e2e_curve.csv",
        &["round", "train_loss", "eval_loss", "cum_uplink_bits"],
        &rows,
    )?;

    let first = hist.reports.first().unwrap().train_loss;
    let (final_loss, _) = hist.final_eval().unwrap();
    println!(
        "\ndone in {wall:.1}s: loss {first:.3} → {final_loss:.3} \
         (uniform-random baseline = ln {VOCAB} = {:.3}); uplink {:.2e} bits",
        (VOCAB as f64).ln(),
        hist.total_uplink()
    );
    println!("loss curve → transformer_e2e_curve.csv");
    if final_loss >= first {
        return Err("loss did not decrease".into());
    }
    Ok(())
}
