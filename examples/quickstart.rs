//! Quickstart: train a small MLP federatively with SPARSIGNSGD and compare
//! it against plain signSGD under Dirichlet(0.3) label skew.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use sparsignd::prelude::*;
use sparsignd::config::ExperimentConfig;
use sparsignd::experiments::run_classification;

fn main() {
    // The fast preset: 20 workers, Dirichlet(0.3) skew, a 32-dim synthetic
    // task, and three algorithms — signSGD, SPARSIGNSGD(B=1) and
    // EF-SPARSIGNSGD — over two seeds.
    let cfg = ExperimentConfig::fast_preset();
    println!(
        "task {:?}, model {}, {} workers, α = {}\n",
        cfg.task.label(),
        cfg.model.label(),
        cfg.workers,
        cfg.alpha
    );
    let report = run_classification(&cfg);
    println!("{}", report.table());
    println!(
        "partition skew (mean max class fraction): {:.3}",
        report.mean_max_class_fraction
    );

    // The library pieces are directly usable too — compress one gradient:
    let mut rng = Pcg64::seed_from(0);
    let gradient: Vec<f32> = (0..512).map(|i| ((i % 13) as f32 - 6.0) / 40.0).collect();
    let mut comp = SparsignCompressor { budget: 1.0 };
    let msg = comp.compress(&gradient, &mut rng);
    println!(
        "\nsparsign(B=1) on a {}-dim gradient: {} non-zeros, {:.0} bits \
         (dense fp32 would be {} bits)",
        gradient.len(),
        msg.nnz(),
        msg.bits(),
        gradient.len() * 32
    );
}
