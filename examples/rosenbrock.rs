//! Figures 1 & 2 (§6.1): minimize the d=10 Rosenbrock function with 100
//! workers where 80 see sign-flipped scaled objectives (eq. 11), and
//! measure the probability of wrong aggregation.
//!
//! ```bash
//! cargo run --release --example rosenbrock            # fast
//! cargo run --release --example rosenbrock -- 10000   # more rounds
//! ```
//!
//! Emits `fig1.csv` / `fig2.csv` next to the binary's working directory.

use sparsignd::experiments::{run_fig1, run_fig2, RosenbrockSeries};
use sparsignd::metrics::write_csv;

fn dump(fig: &str, series: &[RosenbrockSeries]) {
    println!("## {fig}");
    for s in series {
        println!(
            "  {:<28} wrong-aggregation {:.3}   F: {:>6.2} → {:>12.2}   {}",
            s.label,
            s.mean_wrong_agg(),
            s.fvalue.first().unwrap(),
            s.final_value(),
            if s.final_value() > *s.fvalue.first().unwrap() {
                "DIVERGES"
            } else {
                "converges"
            }
        );
    }
    let path = format!("{}.csv", fig.to_lowercase().replace([' ', '.'], ""));
    let mut headers = vec!["round".to_string()];
    for s in series {
        headers.push(format!("{}:wrong_agg", s.label));
        headers.push(format!("{}:F", s.label));
    }
    let rows: Vec<Vec<String>> = (0..series[0].fvalue.len())
        .map(|t| {
            let mut row = vec![t.to_string()];
            for s in series {
                row.push(format!("{:.6}", s.wrong_agg[t]));
                row.push(format!("{:.6}", s.fvalue[t]));
            }
            row
        })
        .collect();
    let h: Vec<&str> = headers.iter().map(|x| x.as_str()).collect();
    write_csv(&path, &h, &rows).expect("csv");
    println!("  series → {path}\n");
}

fn main() {
    let rounds: usize = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(3_000);
    let lr = 0.01;
    println!(
        "Rosenbrock d=10, M=100 workers, 80 sign-flipped (eq. 11), lr={lr}, {rounds} rounds\n"
    );
    dump("Fig 1", &run_fig1(rounds, lr, 7));
    dump("Fig 2", &run_fig2(rounds, lr, 7));
    println!(
        "Expected shape (paper Fig. 1/2): deterministic sign has wrong-aggregation ≈ 1\n\
         and diverges; sparsign stays < 1/2 and descends, faster with more sampling."
    );
}
