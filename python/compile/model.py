"""Layer-2 JAX models: forward/backward graphs lowered AOT to HLO text and
executed from the rust coordinator via PJRT.

All models take their parameters as ONE FLAT f32 vector whose memory
layout matches the rust pure-implementations exactly (per layer: weight
matrix ``(out, in)`` row-major, then bias ``(out,)``) — so the rust
compressors, the HLO-backed path and the pure-rust path all see the same
coordinate indexing, and cross-checking them is an equality test.

The ``*_grad_compress`` variants fuse the Layer-1 Pallas ``sparsign``
kernel after backprop, so compression lowers into the same HLO module and
the whole worker step (fwd + bwd + ternarize) is a single PJRT execution.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels.sparsign import sparsign


# --------------------------------------------------------------------- MLP
@dataclass(frozen=True)
class MlpSpec:
    """Widths [inputs, hidden..., classes], matching rust `model::Mlp`."""

    widths: tuple[int, ...]

    @property
    def dim(self) -> int:
        d = 0
        for i in range(len(self.widths) - 1):
            d += self.widths[i] * self.widths[i + 1] + self.widths[i + 1]
        return d

    def slices(self):
        """(offset, (out, in)) per layer weight + (offset, out) per bias."""
        off = 0
        out = []
        for i in range(len(self.widths) - 1):
            n_in, n_out = self.widths[i], self.widths[i + 1]
            w_off = off
            b_off = off + n_in * n_out
            out.append((w_off, b_off, n_in, n_out))
            off = b_off + n_out
        return out

    def unflatten(self, flat):
        layers = []
        for w_off, b_off, n_in, n_out in self.slices():
            w = flat[w_off : w_off + n_in * n_out].reshape(n_out, n_in)
            b = flat[b_off : b_off + n_out]
            layers.append((w, b))
        return layers


PAPER_FMNIST = MlpSpec((784, 256, 128, 10))


def mlp_logits(spec: MlpSpec, flat_params, x):
    """Forward pass: ReLU MLP, logits out."""
    h = x
    layers = spec.unflatten(flat_params)
    for i, (w, b) in enumerate(layers):
        h = h @ w.T + b
        if i + 1 < len(layers):
            h = jax.nn.relu(h)
    return h


def mlp_loss(spec: MlpSpec, flat_params, x, y_onehot):
    """Mean softmax cross-entropy."""
    logits = mlp_logits(spec, flat_params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))


def mlp_grad(spec: MlpSpec):
    """(flat_params, x, y_onehot) -> (loss, flat_grad)."""

    def fn(flat_params, x, y_onehot):
        loss, grad = jax.value_and_grad(lambda p: mlp_loss(spec, p, x, y_onehot))(
            flat_params
        )
        return loss, grad

    return fn


def mlp_grad_compress(spec: MlpSpec, budget: float):
    """(flat_params, x, y_onehot, key) -> (loss, ternary codes).

    The full worker step of Algorithm 1 with Q = sparsign: fwd/bwd then the
    Pallas kernel, fused into one HLO module. ``key`` is a uint32[2]
    threefry key; the uniforms are generated inside the graph so the rust
    side only supplies a per-(round, worker) key.
    """

    def fn(flat_params, x, y_onehot, key):
        loss, grad = jax.value_and_grad(lambda p: mlp_loss(spec, p, x, y_onehot))(
            flat_params
        )
        u = jax.random.uniform(key, grad.shape, dtype=grad.dtype)
        codes = sparsign(grad, u, budget)
        return loss, codes

    return fn


# -------------------------------------------------------- tiny transformer
@dataclass(frozen=True)
class TransformerSpec:
    """Decoder-only LM sized for the e2e federated-training example
    (scaled down from the paper-scale ambition to fit the single-core
    sandbox; the architecture — pre-LN attention + MLP blocks — is the
    standard one, so widening it is a config change)."""

    vocab: int = 64
    seq: int = 32
    d_model: int = 64
    heads: int = 2
    layers: int = 2
    d_ff: int = 128

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.heads == 0
        return self.d_model // self.heads

    def shapes(self):
        """Ordered (name, shape) parameter list (flat layout contract)."""
        s = [("embed", (self.vocab, self.d_model)), ("pos", (self.seq, self.d_model))]
        for l in range(self.layers):
            s += [
                (f"l{l}.ln1_g", (self.d_model,)),
                (f"l{l}.ln1_b", (self.d_model,)),
                (f"l{l}.wq", (self.d_model, self.d_model)),
                (f"l{l}.wk", (self.d_model, self.d_model)),
                (f"l{l}.wv", (self.d_model, self.d_model)),
                (f"l{l}.wo", (self.d_model, self.d_model)),
                (f"l{l}.ln2_g", (self.d_model,)),
                (f"l{l}.ln2_b", (self.d_model,)),
                (f"l{l}.w1", (self.d_ff, self.d_model)),
                (f"l{l}.b1", (self.d_ff,)),
                (f"l{l}.w2", (self.d_model, self.d_ff)),
                (f"l{l}.b2", (self.d_model,)),
            ]
        s += [("lnf_g", (self.d_model,)), ("lnf_b", (self.d_model,))]
        return s

    @property
    def dim(self) -> int:
        return sum(int(jnp.prod(jnp.array(shape))) for _, shape in self.shapes())

    def unflatten(self, flat):
        params = {}
        off = 0
        for name, shape in self.shapes():
            n = 1
            for v in shape:
                n *= v
            params[name] = flat[off : off + n].reshape(shape)
            off += n
        return params


def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def transformer_logits(spec: TransformerSpec, flat_params, tokens):
    """tokens: int32[batch, seq] -> logits[batch, seq, vocab] (tied embed)."""
    p = spec.unflatten(flat_params)
    h = p["embed"][tokens] + p["pos"][None, :, :]
    mask = jnp.tril(jnp.ones((spec.seq, spec.seq), dtype=bool))
    for l in range(spec.layers):
        x = _layernorm(h, p[f"l{l}.ln1_g"], p[f"l{l}.ln1_b"])
        b, t, d = x.shape
        def split(w):
            y = x @ w.T
            return y.reshape(b, t, spec.heads, spec.head_dim).transpose(0, 2, 1, 3)
        q, k, v = split(p[f"l{l}.wq"]), split(p[f"l{l}.wk"]), split(p[f"l{l}.wv"])
        att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(spec.head_dim)
        att = jnp.where(mask[None, None, :, :], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        y = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, d)
        h = h + y @ p[f"l{l}.wo"].T
        x = _layernorm(h, p[f"l{l}.ln2_g"], p[f"l{l}.ln2_b"])
        ff = jax.nn.relu(x @ p[f"l{l}.w1"].T + p[f"l{l}.b1"]) @ p[f"l{l}.w2"].T + p[
            f"l{l}.b2"
        ]
        h = h + ff
    h = _layernorm(h, p["lnf_g"], p["lnf_b"])
    return h @ p["embed"].T  # weight tying


def transformer_loss(spec: TransformerSpec, flat_params, tokens, targets):
    logits = transformer_logits(spec, flat_params, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(picked)


def transformer_grad(spec: TransformerSpec):
    """(flat_params, tokens, targets) -> (loss, flat_grad)."""

    def fn(flat_params, tokens, targets):
        loss, grad = jax.value_and_grad(
            lambda p: transformer_loss(spec, p, tokens, targets)
        )(flat_params)
        return loss, grad

    return fn


def transformer_grad_compress(spec: TransformerSpec, budget: float):
    """Worker step with fused sparsign, as in `mlp_grad_compress`."""

    def fn(flat_params, tokens, targets, key):
        loss, grad = jax.value_and_grad(
            lambda p: transformer_loss(spec, p, tokens, targets)
        )(flat_params)
        u = jax.random.uniform(key, grad.shape, dtype=grad.dtype)
        codes = sparsign(grad, u, budget)
        return loss, codes

    return fn


def transformer_init(spec: TransformerSpec, key) -> jnp.ndarray:
    """He/Xavier-style init, returned flat (matches `shapes()` order)."""
    parts = []
    for name, shape in spec.shapes():
        key, sub = jax.random.split(key)
        if name.endswith(("_g",)):
            parts.append(jnp.ones(shape, jnp.float32).reshape(-1))
        elif name.endswith(("_b", ".b1", ".b2")):
            parts.append(jnp.zeros(shape, jnp.float32).reshape(-1))
        else:
            fan_in = shape[-1]
            std = (1.0 / fan_in) ** 0.5
            parts.append(
                (jax.random.normal(sub, shape, jnp.float32) * std).reshape(-1)
            )
    return jnp.concatenate(parts)


# -------------------------------------------------------------- rosenbrock
def rosenbrock_value(x):
    """Standard Rosenbrock (see rust `model::rosenbrock` for the eq. (10)
    typo note)."""
    a = x[1:] - x[:-1] ** 2
    b = 1.0 - x[:-1]
    return jnp.sum(100.0 * a * a + b * b)


@functools.partial(jax.jit)
def rosenbrock_grad(x):
    """x: f32[n] -> (value, grad)."""
    return jax.value_and_grad(rosenbrock_value)(x)
