"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth
for the pytest/hypothesis suite (and the reference implementation for
roofline comparison in §Perf).
"""

from __future__ import annotations

import jax.numpy as jnp


def sparsign_ref(g, u, budget: float):
    """Definition 1, straight-line jnp: sign(g) with prob min(1, B·|g|)."""
    p = jnp.minimum(jnp.abs(g) * budget, 1.0)
    return jnp.where(u < p, jnp.sign(g), jnp.zeros_like(g))


def majority_vote_ref(votes):
    """sign(Σ_m votes_m) with sign(0) = 0."""
    return jnp.sign(jnp.sum(votes, axis=0))


def expected_nnz_ref(g, budget: float):
    """E[#nonzero] = Σ_i min(1, B·|g_i|) (Definition 1)."""
    return jnp.sum(jnp.minimum(jnp.abs(g) * budget, 1.0))


def scaled_sign_ref(x):
    """The server-side α-approximate compressor C(x) = (‖x‖₁/d)·sign(x)."""
    d = x.size
    return (jnp.sum(jnp.abs(x)) / d) * jnp.sign(x)
