"""Layer-1 Pallas kernels: the paper's sparsign compressor (Definition 1)
and the majority-vote aggregator.

The sparsign compressor is the per-coordinate hot spot of the whole
system: every selected worker ternarizes its full gradient every round.

TPU mapping (DESIGN.md §Hardware-Adaptation): the kernel is element-wise
VPU work. Gradients are viewed as ``(rows, 128)`` — 128 is the TPU lane
width — and streamed HBM→VMEM in ``(BLOCK_ROWS, 128)`` blocks via
``BlockSpec`` over a 1-D grid. Randomness enters as a second streamed
input (uniform draws produced by counter-based threefry *in the L2
graph*), keeping the kernel deterministic given its inputs.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the same program runs
on the rust CPU client. Real-TPU performance is estimated from the VMEM
footprint in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# TPU f32 tiling: lane width 128, sublane multiple of 8.
LANES = 128
BLOCK_ROWS = 256  # (256, 128) f32 block = 128 KiB; g + u + out ≈ 384 KiB VMEM


def _sparsign_block_kernel(g_ref, u_ref, o_ref, *, budget: float):
    """One (BLOCK_ROWS, LANES) block: keep sign(g) where u < min(1,B·|g|)."""
    g = g_ref[...]
    u = u_ref[...]
    p = jnp.minimum(jnp.abs(g) * budget, 1.0)
    keep = u < p
    o_ref[...] = jnp.where(keep, jnp.sign(g), 0.0).astype(o_ref.dtype)


def _pad_to_grid(v: jax.Array) -> tuple[jax.Array, int]:
    """Flatten and zero-pad to a whole number of (BLOCK_ROWS, LANES) blocks."""
    flat = v.reshape(-1)
    n = flat.shape[0]
    block = BLOCK_ROWS * LANES
    padded = ((n + block - 1) // block) * block
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    return flat.reshape(-1, LANES), n


@functools.partial(jax.jit, static_argnames=("budget",))
def sparsign(g: jax.Array, u: jax.Array, budget: float) -> jax.Array:
    """Apply sparsign with compression budget ``B = budget``.

    Args:
      g: gradient, any shape/float dtype.
      u: uniform [0,1) draws, same shape as ``g``.
      budget: the paper's ``B`` (keep-probability per unit magnitude).

    Returns:
      Ternary codes in {-1, 0, +1}, same shape/dtype as ``g``.
      ``E[out] = B·g`` wherever ``B·|g| ≤ 1`` (Remark 7 clipping above).
    """
    if g.shape != u.shape:
        raise ValueError(f"g {g.shape} and u {u.shape} must match")
    if budget < 0:
        raise ValueError(f"budget must be >= 0, got {budget}")
    g2, n = _pad_to_grid(g)
    u2, _ = _pad_to_grid(u)
    rows = g2.shape[0]
    grid = rows // BLOCK_ROWS
    out = pl.pallas_call(
        functools.partial(_sparsign_block_kernel, budget=float(budget)),
        out_shape=jax.ShapeDtypeStruct(g2.shape, g.dtype),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        interpret=True,
    )(g2, u2)
    return out.reshape(-1)[:n].reshape(g.shape)


def _majority_block_kernel(q_ref, o_ref):
    """Column-block majority vote: sign of the vote sum over workers."""
    s = jnp.sum(q_ref[...], axis=0)
    o_ref[...] = jnp.sign(s).astype(o_ref.dtype)


@jax.jit
def majority_vote(votes: jax.Array) -> jax.Array:
    """Majority vote over ``votes[M, d]`` ternary messages → ``sign(Σ_m)``.

    Ties (vote sum 0) return 0, matching the ternary aggregation analysis.
    """
    if votes.ndim != 2:
        raise ValueError(f"votes must be (workers, dim), got {votes.shape}")
    m, d = votes.shape
    pad = (LANES - d % LANES) % LANES
    v = jnp.pad(votes, ((0, 0), (0, pad))) if pad else votes
    cols = v.shape[1]
    out = pl.pallas_call(
        _majority_block_kernel,
        out_shape=jax.ShapeDtypeStruct((cols,), votes.dtype),
        grid=(cols // LANES,),
        in_specs=[pl.BlockSpec((m, LANES), lambda i: (0, i))],
        out_specs=pl.BlockSpec((LANES,), lambda i: (i,)),
        interpret=True,
    )(v)
    return out[:d]


def sparsign_vmem_report(budget: float) -> dict:
    """Static VMEM-footprint estimate for the §Perf TPU analysis."""
    block_bytes = BLOCK_ROWS * LANES * 4
    return {
        "block_shape": (BLOCK_ROWS, LANES),
        "inputs_bytes": 2 * block_bytes,  # g + u streams
        "output_bytes": block_bytes,
        "total_vmem_bytes": 3 * block_bytes,
        "vmem_budget_bytes": 16 * 1024 * 1024,
        "utilization": 3 * block_bytes / (16 * 1024 * 1024),
        "budget": budget,
        "unit": "VPU (element-wise); MXU idle on this path",
    }
