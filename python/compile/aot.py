"""AOT lowering: every (model, shape) variant → ``artifacts/<name>.hlo.txt``.

HLO *text* is the interchange format (NOT ``lowered.compile()`` /
``.serialize()``): jax ≥ 0.5 serializes HloModuleProto with 64-bit
instruction ids, which the rust side's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Each artifact gets a sidecar line in ``artifacts/manifest.txt``:

    <name> :: in0=f32[235146];in1=f32[64,784];... :: out=tuple(f32[],f32[235146])

which the rust runtime parses to validate shapes before executing.

Run ``python -m compile.aot --out ../artifacts`` (the Makefile's
``make artifacts`` does this and is a no-op when sources are unchanged).
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

# Batch sizes baked into the artifacts (the rust engine pads/chunks to
# these; keep in sync with runtime::artifact::BATCH docs).
MLP_BATCH = 64
TRANSFORMER_BATCH = 8


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the rust
    side unwraps with to_tuple())."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _fmt_shape(s: jax.ShapeDtypeStruct) -> str:
    dt = jnp.dtype(s.dtype).name
    dims = ",".join(str(d) for d in s.shape)
    return f"{dt}[{dims}]"


def artifact_suite():
    """(name, fn, example_args) for every artifact we ship."""
    f32 = jnp.float32
    suite = []

    # Paper §C.2 Fashion-MNIST MLP: grad, grad+sparsign-fused, logits.
    spec = M.PAPER_FMNIST
    p = jax.ShapeDtypeStruct((spec.dim,), f32)
    x = jax.ShapeDtypeStruct((MLP_BATCH, spec.widths[0]), f32)
    y = jax.ShapeDtypeStruct((MLP_BATCH, spec.widths[-1]), f32)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    suite.append(("mlp_fmnist_grad", M.mlp_grad(spec), (p, x, y)))
    suite.append(
        ("mlp_fmnist_grad_sparsign_b1", M.mlp_grad_compress(spec, 1.0), (p, x, y, key))
    )
    suite.append(
        ("mlp_fmnist_logits", lambda pp, xx: (M.mlp_logits(spec, pp, xx),), (p, x))
    )

    # Small MLP variant for the fast integration tests (dim 32 task).
    small = M.MlpSpec((32, 32, 5))
    sp = jax.ShapeDtypeStruct((small.dim,), f32)
    sx = jax.ShapeDtypeStruct((MLP_BATCH, 32), f32)
    sy = jax.ShapeDtypeStruct((MLP_BATCH, 5), f32)
    suite.append(("mlp_small_grad", M.mlp_grad(small), (sp, sx, sy)))
    suite.append(
        ("mlp_small_logits", lambda pp, xx: (M.mlp_logits(small, pp, xx),), (sp, sx))
    )

    # Tiny transformer LM for the e2e example.
    tspec = M.TransformerSpec()
    tp = jax.ShapeDtypeStruct((tspec.dim,), f32)
    tok = jax.ShapeDtypeStruct((TRANSFORMER_BATCH, tspec.seq), jnp.int32)
    suite.append(("transformer_grad", M.transformer_grad(tspec), (tp, tok, tok)))
    suite.append(
        ("transformer_init", lambda k: (M.transformer_init(tspec, k),), (key,))
    )
    suite.append(
        (
            "transformer_grad_sparsign_b1",
            M.transformer_grad_compress(tspec, 1.0),
            (tp, tok, tok, key),
        )
    )

    # Rosenbrock (§6.1), d = 10.
    rx = jax.ShapeDtypeStruct((10,), f32)
    suite.append(("rosenbrock_grad", M.rosenbrock_grad, (rx,)))
    return suite


def lower_all(out_dir: str, only: str | None = None) -> list[str]:
    os.makedirs(out_dir, exist_ok=True)
    # Merge with any existing manifest so `--only` refreshes incrementally.
    manifest: dict[str, str] = {}
    man_path = os.path.join(out_dir, "manifest.txt")
    if os.path.exists(man_path):
        for line in open(man_path):
            line = line.strip()
            if " :: " in line:
                manifest[line.split(" :: ")[0]] = line
    written = []
    for name, fn, args in artifact_suite():
        if only and only not in name:
            continue
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        ins = ";".join(f"in{i}={_fmt_shape(a)}" for i, a in enumerate(args))
        manifest[name] = f"{name} :: {ins}"
        written.append(path)
        print(f"  {name}: {len(text)} chars, inputs {ins}")
    with open(man_path, "w") as f:
        f.write("\n".join(manifest[k] for k in sorted(manifest)) + "\n")
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--only", default=None, help="substring filter")
    args = ap.parse_args()
    print(f"AOT-lowering artifacts to {args.out}")
    written = lower_all(args.out, args.only)
    print(f"wrote {len(written)} artifacts + manifest.txt")


if __name__ == "__main__":
    main()
