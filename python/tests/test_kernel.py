"""L1 correctness: the Pallas sparsign kernel vs the pure-jnp oracle.

This is the core correctness signal for the compute layer — hypothesis
sweeps shapes, dtypes and budgets; statistical tests pin the Definition 1
semantics (keep-probability ∝ magnitude, unbiasedness below clipping).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import (
    expected_nnz_ref,
    majority_vote_ref,
    scaled_sign_ref,
    sparsign_ref,
)
from compile.kernels.sparsign import (
    BLOCK_ROWS,
    LANES,
    majority_vote,
    sparsign,
    sparsign_vmem_report,
)


def _gu(shape, seed, scale=1.0, dtype=jnp.float32):
    kg, ku = jax.random.split(jax.random.PRNGKey(seed))
    g = (jax.random.normal(kg, shape) * scale).astype(dtype)
    u = jax.random.uniform(ku, shape, dtype=dtype)
    return g, u


# ------------------------------------------------------ kernel == oracle
@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=5000),
    budget=st.floats(min_value=0.0, max_value=20.0, allow_nan=False),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_matches_ref_1d(n, budget, seed):
    g, u = _gu((n,), seed)
    got = sparsign(g, u, budget)
    want = sparsign_ref(g, u, budget)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(min_value=1, max_value=70),
    cols=st.integers(min_value=1, max_value=200),
    budget=st.floats(min_value=0.01, max_value=5.0, allow_nan=False),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_matches_ref_2d(rows, cols, budget, seed):
    g, u = _gu((rows, cols), seed)
    got = sparsign(g, u, budget)
    want = sparsign_ref(g, u, budget)
    assert got.shape == g.shape
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtypes(dtype):
    g, u = _gu((333,), 7, dtype=dtype)
    got = sparsign(g, u, 0.7)
    want = sparsign_ref(g, u, 0.7)
    assert got.dtype == dtype
    np.testing.assert_array_equal(
        np.asarray(got, dtype=np.float32), np.asarray(want, dtype=np.float32)
    )


def test_exact_block_boundary_shapes():
    # Exactly one block, one block ± 1, many blocks.
    block = BLOCK_ROWS * LANES
    for n in [block - 1, block, block + 1, 3 * block]:
        g, u = _gu((n,), n)
        np.testing.assert_array_equal(
            np.asarray(sparsign(g, u, 0.3)), np.asarray(sparsign_ref(g, u, 0.3))
        )


# ---------------------------------------------------- Definition 1 semantics
def test_output_is_ternary_and_sign_consistent():
    g, u = _gu((4096,), 1, scale=3.0)
    out = np.asarray(sparsign(g, u, 0.5))
    assert set(np.unique(out)).issubset({-1.0, 0.0, 1.0})
    gnp = np.asarray(g)
    nz = out != 0
    assert np.all(np.sign(gnp[nz]) == out[nz])


def test_zero_budget_and_zero_gradient():
    g, u = _gu((512,), 2)
    assert np.all(np.asarray(sparsign(g, u, 0.0)) == 0)
    z = jnp.zeros((512,))
    assert np.all(np.asarray(sparsign(z, u, 100.0)) == 0)


def test_clipping_regime_deterministic():
    # B·|g| ≥ 1 everywhere ⇒ output = sign(g) exactly (Remark 7).
    g = jnp.array([2.0, -3.0, 1.5, -1.0])
    u = jnp.array([0.999, 0.999, 0.999, 0.999])
    out = np.asarray(sparsign(g, u, 1.0))
    np.testing.assert_array_equal(out, [1.0, -1.0, 1.0, -1.0])


def test_expected_nnz_matches_definition():
    g, _ = _gu((2048,), 3, scale=0.5)
    budget = 0.8
    trials = 300
    total = 0
    for s in range(trials):
        u = jax.random.uniform(jax.random.PRNGKey(1000 + s), g.shape)
        total += int(np.count_nonzero(np.asarray(sparsign(g, u, budget))))
    got = total / trials
    want = float(expected_nnz_ref(g, budget))
    assert abs(got - want) < 0.03 * want, (got, want)


def test_unbiased_below_clipping():
    # E[Q(g)] = B·g for B·|g| ≤ 1.
    g = jnp.array([0.5, -0.8, 0.1, -0.3])
    budget = 0.9
    trials = 20_000
    keys = jax.random.split(jax.random.PRNGKey(5), trials)
    u = jax.vmap(lambda k: jax.random.uniform(k, g.shape))(keys)
    outs = jax.vmap(lambda uu: sparsign_ref(g, uu, budget))(u)
    mean = np.asarray(jnp.mean(outs, axis=0))
    np.testing.assert_allclose(mean, budget * np.asarray(g), atol=0.02)


def test_invalid_inputs_raise():
    g, u = _gu((8,), 4)
    with pytest.raises(ValueError):
        sparsign(g, u[:4], 1.0)
    with pytest.raises(ValueError):
        sparsign(g, u, -1.0)


# ------------------------------------------------------------ majority vote
@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=33),
    d=st.integers(min_value=1, max_value=700),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_majority_vote_matches_ref(m, d, seed):
    votes = jax.random.randint(jax.random.PRNGKey(seed), (m, d), -1, 2).astype(
        jnp.float32
    )
    got = majority_vote(votes)
    want = majority_vote_ref(votes)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_majority_vote_ties_are_zero():
    votes = jnp.array([[1.0, -1.0, 0.0], [-1.0, 1.0, 0.0]])
    np.testing.assert_array_equal(np.asarray(majority_vote(votes)), [0.0, 0.0, 0.0])


def test_majority_vote_rejects_bad_rank():
    with pytest.raises(ValueError):
        majority_vote(jnp.ones((3,)))


# ----------------------------------------------------------- scaled sign ref
def test_scaled_sign_ref_alpha_approximate():
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(9), (256,)))
    c = np.asarray(scaled_sign_ref(jnp.array(x)))
    err = float(np.sum((c - x) ** 2))
    l1, l2sq = float(np.sum(np.abs(x))), float(np.sum(x * x))
    alpha = l1 * l1 / (x.size * l2sq)
    assert err <= (1.0 - alpha) * l2sq + 1e-4


# ------------------------------------------------------------- VMEM budget
def test_vmem_report_within_budget():
    r = sparsign_vmem_report(1.0)
    assert r["total_vmem_bytes"] < r["vmem_budget_bytes"]
    assert 0.0 < r["utilization"] < 0.25
