"""L2 correctness: model graphs, the flat-parameter layout contract with
the rust side, and the fused grad+compress path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


# --------------------------------------------------------------- MLP layout
def test_mlp_dim_matches_rust_layout():
    spec = M.PAPER_FMNIST
    assert spec.dim == 784 * 256 + 256 + 256 * 128 + 128 + 128 * 10 + 10


def test_unflatten_roundtrip_layout():
    spec = M.MlpSpec((3, 4, 2))
    flat = jnp.arange(spec.dim, dtype=jnp.float32)
    layers = spec.unflatten(flat)
    # First weight is (4, 3) row-major from offset 0.
    np.testing.assert_array_equal(
        np.asarray(layers[0][0]), np.arange(12, dtype=np.float32).reshape(4, 3)
    )
    # First bias follows.
    np.testing.assert_array_equal(np.asarray(layers[0][1]), [12, 13, 14, 15])
    # Second layer weight (2, 4) then bias (2,).
    assert layers[1][0].shape == (2, 4)
    assert layers[1][1].shape == (2,)


def test_mlp_loss_and_grad_shapes():
    spec = M.MlpSpec((6, 5, 3))
    key = jax.random.PRNGKey(0)
    p = jax.random.normal(key, (spec.dim,)) * 0.1
    x = jax.random.normal(key, (4, 6))
    y = jax.nn.one_hot(jnp.array([0, 1, 2, 1]), 3)
    loss, grad = M.mlp_grad(spec)(p, x, y)
    assert loss.shape == ()
    assert grad.shape == (spec.dim,)
    assert float(loss) > 0


def test_mlp_grad_is_descent_direction():
    spec = M.MlpSpec((6, 8, 3))
    key = jax.random.PRNGKey(1)
    p = jax.random.normal(key, (spec.dim,)) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(2), (16, 6))
    y = jax.nn.one_hot(jax.random.randint(jax.random.PRNGKey(3), (16,), 0, 3), 3)
    fn = M.mlp_grad(spec)
    l0, g = fn(p, x, y)
    l1, _ = fn(p - 0.1 * g, x, y)
    assert float(l1) < float(l0)


def test_mlp_grad_compress_fuses_kernel():
    spec = M.MlpSpec((6, 5, 3))
    key = jax.random.PRNGKey(4)
    p = jax.random.normal(key, (spec.dim,)) * 0.1
    x = jax.random.normal(key, (4, 6))
    y = jax.nn.one_hot(jnp.array([0, 1, 2, 1]), 3)
    loss, codes = M.mlp_grad_compress(spec, 5.0)(p, x, y, jax.random.PRNGKey(7))
    c = np.asarray(codes)
    assert set(np.unique(c)).issubset({-1.0, 0.0, 1.0})
    # Codes' signs agree with the raw gradient where non-zero.
    _, grad = M.mlp_grad(spec)(p, x, y)
    g = np.asarray(grad)
    nz = c != 0
    assert np.all(np.sign(g[nz]) == c[nz])
    # Same key ⇒ same codes (stateless RNG contract with the rust side).
    _, codes2 = M.mlp_grad_compress(spec, 5.0)(p, x, y, jax.random.PRNGKey(7))
    np.testing.assert_array_equal(c, np.asarray(codes2))


# ------------------------------------------------------------- transformer
def test_transformer_dim_and_unflatten():
    spec = M.TransformerSpec()
    flat = jnp.zeros((spec.dim,), jnp.float32)
    params = spec.unflatten(flat)
    assert params["embed"].shape == (spec.vocab, spec.d_model)
    assert params["l0.w1"].shape == (spec.d_ff, spec.d_model)
    total = sum(int(np.prod(v.shape)) for v in params.values())
    assert total == spec.dim


def test_transformer_causality():
    # Changing a future token must not change past logits.
    spec = M.TransformerSpec(layers=1)
    p = M.transformer_init(spec, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (1, spec.seq), 0, spec.vocab)
    base = M.transformer_logits(spec, p, tok)
    tok2 = tok.at[0, -1].set((tok[0, -1] + 1) % spec.vocab)
    pert = M.transformer_logits(spec, p, tok2)
    np.testing.assert_allclose(
        np.asarray(base[0, : spec.seq - 1]),
        np.asarray(pert[0, : spec.seq - 1]),
        atol=1e-5,
    )
    assert not np.allclose(np.asarray(base[0, -1]), np.asarray(pert[0, -1]))


def test_transformer_loss_decreases_under_sgd():
    spec = M.TransformerSpec(layers=1, seq=16)
    p = M.transformer_init(spec, jax.random.PRNGKey(2))
    # Learnable toy sequence: next token = (token + 1) % vocab.
    tok = (jnp.arange(16)[None, :] + jnp.arange(4)[:, None]) % spec.vocab
    tgt = (tok + 1) % spec.vocab
    fn = jax.jit(M.transformer_grad(spec))
    l0, _ = fn(p, tok, tgt)
    for _ in range(30):
        _, g = fn(p, tok, tgt)
        p = p - 0.5 * g
    l1, _ = fn(p, tok, tgt)
    assert float(l1) < 0.7 * float(l0), (float(l0), float(l1))


def test_transformer_grad_compress_is_ternary():
    spec = M.TransformerSpec(layers=1, seq=8)
    p = M.transformer_init(spec, jax.random.PRNGKey(3))
    tok = jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0, spec.vocab)
    loss, codes = M.transformer_grad_compress(spec, 10.0)(
        p, tok, tok, jax.random.PRNGKey(5)
    )
    c = np.asarray(codes)
    assert c.shape == (spec.dim,)
    assert set(np.unique(c)).issubset({-1.0, 0.0, 1.0})
    assert float(loss) > 0


# -------------------------------------------------------------- rosenbrock
def test_rosenbrock_matches_closed_form():
    x = jnp.array([0.5, -1.0, 2.0, 0.1, 1.0, -0.3, 0.0, 0.7, -1.2, 1.0])
    val, grad = M.rosenbrock_grad(x)
    xn = np.asarray(x, dtype=np.float64)
    want = np.sum(100.0 * (xn[1:] - xn[:-1] ** 2) ** 2 + (1.0 - xn[:-1]) ** 2)
    assert abs(float(val) - want) / want < 1e-5
    # Closed-form gradient.
    g = np.zeros_like(xn)
    t = xn[1:] - xn[:-1] ** 2
    g[:-1] += -400.0 * xn[:-1] * t - 2.0 * (1.0 - xn[:-1])
    g[1:] += 200.0 * t
    np.testing.assert_allclose(np.asarray(grad), g, rtol=1e-4, atol=1e-3)


def test_rosenbrock_minimum():
    ones = jnp.ones((10,))
    val, grad = M.rosenbrock_grad(ones)
    assert float(val) < 1e-10
    np.testing.assert_allclose(np.asarray(grad), 0.0, atol=1e-5)
