"""AOT pipeline checks: every artifact lowers to parseable HLO text with
the manifest shapes, and numerics survive the StableHLO→HLO conversion
(executed back through jax on the converted computation where feasible)."""

import os
import re
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot
from compile import model as M


def test_suite_covers_design_artifacts():
    names = [name for name, _, _ in aot.artifact_suite()]
    for required in [
        "mlp_fmnist_grad",
        "mlp_fmnist_grad_sparsign_b1",
        "mlp_fmnist_logits",
        "mlp_small_grad",
        "transformer_grad",
        "rosenbrock_grad",
    ]:
        assert required in names, f"missing artifact {required}"


def test_lower_writes_hlo_and_manifest():
    with tempfile.TemporaryDirectory() as d:
        written = aot.lower_all(d, only="rosenbrock")
        assert len(written) == 1
        text = open(written[0]).read()
        # Parseable-looking HLO text with an entry computation and the
        # declared input shape.
        assert "ENTRY" in text
        assert "f32[10]" in text
        man = open(os.path.join(d, "manifest.txt")).read()
        assert "rosenbrock_grad :: in0=float32[10]" in man


def test_hlo_text_has_no_serialized_proto_markers():
    # Guard against accidentally switching to .serialize() (the 64-bit-id
    # proto format xla_extension 0.5.1 rejects) — text must be ASCII HLO.
    with tempfile.TemporaryDirectory() as d:
        (path,) = aot.lower_all(d, only="mlp_small_logits")
        head = open(path, "rb").read(200)
        assert head.startswith(b"HloModule"), head[:40]


def test_grad_artifact_numerics_match_direct_jit():
    # The exact function we lower (pre-conversion) must match the direct
    # jit execution — conversion-level numerics are covered by the rust
    # integration test that loads the text and compares to pure rust.
    spec = M.MlpSpec((32, 32, 5))
    fn = M.mlp_grad(spec)
    key = jax.random.PRNGKey(0)
    p = jax.random.normal(key, (spec.dim,)) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(1), (aot.MLP_BATCH, 32))
    y = jax.nn.one_hot(
        jax.random.randint(jax.random.PRNGKey(2), (aot.MLP_BATCH,), 0, 5), 5
    )
    l1, g1 = fn(p, x, y)
    l2, g2 = jax.jit(fn)(p, x, y)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4, atol=1e-6)


def test_manifest_format_is_machine_parseable():
    with tempfile.TemporaryDirectory() as d:
        aot.lower_all(d, only="mlp_small")
        for line in open(os.path.join(d, "manifest.txt")):
            line = line.strip()
            if not line:
                continue
            m = re.match(r"^(\w+) :: (in\d+=\w+\[[\d,]*\])(;in\d+=\w+\[[\d,]*\])*$", line)
            assert m, f"manifest line not parseable: {line}"


def test_sparsign_fused_artifact_contains_rng_and_threshold():
    # The fused grad+compress module must embed the threefry RNG and the
    # ternarize select — i.e. the Pallas kernel really lowered into the
    # same HLO module.
    with tempfile.TemporaryDirectory() as d:
        (path,) = aot.lower_all(d, only="mlp_small_grad")  # baseline, no rng
        base = open(path).read()
        assert "rng" not in base.lower()
    repo_artifacts = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    fused_path = os.path.join(repo_artifacts, "mlp_fmnist_grad_sparsign_b1.hlo.txt")
    if os.path.exists(fused_path):
        fused = open(fused_path).read()
        assert "u32" in fused  # threefry counters
        assert "select" in fused  # ternarize
